"""Pallas kernel validation: sweep shapes/dtypes in interpret mode and
assert_allclose against the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R

KEY = jax.random.PRNGKey(0)


def mk(shape, dtype, key):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# --- flash prefill ----------------------------------------------------------

PREFILL_CASES = [
    # B, Sq, T, H, K, hd, q_offset, causal, window
    (1, 128, 128, 4, 4, 64, 0, True, 0),          # square causal, MHA
    (2, 64, 256, 8, 2, 64, 192, True, 0),         # resumed chunk, GQA
    (1, 100, 300, 4, 1, 128, 200, True, 0),       # ragged (padding paths), MQA
    (2, 128, 384, 4, 2, 64, 256, True, 128),      # local window
    (1, 32, 160, 4, 4, 64, 0, False, 0),          # non-causal (whisper cross)
    (1, 8, 512, 16, 8, 64, 504, True, 0),         # tiny final chunk, long prefix
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", PREFILL_CASES)
def test_flash_prefill_vs_ref(case, dtype):
    B, Sq, T, H, K, hd, qoff, causal, window = case
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = mk((B, Sq, H, hd), dtype, k1)
    k = mk((B, T, K, hd), dtype, k2)
    v = mk((B, T, K, hd), dtype, k3)

    got = ops.prefill_attention(q, k, v, q_offset=qoff, causal=causal,
                                local_window=window, impl="pallas_interpret",
                                block_q=64, block_k=128)
    want = R.chunked_prefill_attention_ref(q, k, v, q_offset=qoff, causal=causal,
                                           local_window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_flash_prefill_kv_len_mask():
    """kv_len < T must ignore the padded cache tail."""
    B, Sq, T, H, K, hd = 1, 32, 256, 4, 2, 64
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = mk((B, Sq, H, hd), jnp.float32, k1)
    k = mk((B, T, K, hd), jnp.float32, k2)
    v = mk((B, T, K, hd), jnp.float32, k3)
    kv_len = 150
    got = ops.prefill_attention(q, k, v, q_offset=kv_len - Sq, kv_len=kv_len,
                                impl="pallas_interpret", block_q=32, block_k=64)
    want = R.chunked_prefill_attention_ref(q[:, :], k[:, :kv_len], v[:, :kv_len],
                                           q_offset=kv_len - Sq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_prefill_chunked_equals_full():
    """Running the kernel chunk-by-chunk (the FlowPrefill execution mode) must
    reproduce the single-shot full prefill exactly."""
    B, S, H, K, hd, chunk = 1, 256, 4, 2, 64, 64
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = mk((B, S, H, hd), jnp.float32, k1)
    k = mk((B, S, K, hd), jnp.float32, k2)
    v = mk((B, S, K, hd), jnp.float32, k3)

    full = ops.prefill_attention(q, k, v, impl="pallas_interpret",
                                 block_q=64, block_k=64)
    pieces = []
    for off in range(0, S, chunk):
        out = ops.prefill_attention(
            q[:, off:off + chunk], k[:, :off + chunk], v[:, :off + chunk],
            q_offset=off, impl="pallas_interpret", block_q=64, block_k=64)
        pieces.append(out)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(pieces, axis=1)),
                               np.asarray(full), rtol=1e-6, atol=1e-6)


# --- flash decode -----------------------------------------------------------

DECODE_CASES = [
    # B, T, H, K, hd, kv_len
    (1, 256, 8, 8, 64, 256),
    (2, 512, 8, 2, 64, 300),      # GQA + partial cache
    (4, 128, 4, 1, 128, 77),      # MQA, ragged kv_len
    (1, 1024, 16, 8, 64, 1000),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", DECODE_CASES)
def test_flash_decode_vs_ref(case, dtype):
    B, T, H, K, hd, kv_len = case
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = mk((B, H, hd), dtype, k1)
    k = mk((B, T, K, hd), dtype, k2)
    v = mk((B, T, K, hd), dtype, k3)
    got = ops.decode_attention(q, k, v, kv_len, impl="pallas_interpret",
                               block_k=128)
    want = R.decode_attention_ref(q, k, v, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# --- xla (blocked) path must agree with ref too -----------------------------

@pytest.mark.parametrize("case", PREFILL_CASES[:4])
def test_blocked_xla_vs_ref(case):
    B, Sq, T, H, K, hd, qoff, causal, window = case
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = mk((B, Sq, H, hd), jnp.float32, k1)
    k = mk((B, T, K, hd), jnp.float32, k2)
    v = mk((B, T, K, hd), jnp.float32, k3)
    got = ops.prefill_attention(q, k, v, q_offset=qoff, causal=causal,
                                local_window=window, impl="xla")
    want = R.chunked_prefill_attention_ref(q, k, v, q_offset=qoff,
                                           causal=causal, local_window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
