"""Cluster simulator validation: single-instance parity with PrefillSim,
goodput scaling with instance count, load-aware dispatch beating round-robin
under bursty arrivals, and decode-phase TPOT/TBT accounting."""
import numpy as np

from repro.core.metrics import max_goodput
from repro.sim.cluster import ClusterSim, simulate_cluster
from repro.sim.costmodel import (A800, LLAMA3_8B, DecodeCostModel,
                                 PrefillCostModel)
from repro.sim.policies import simulate
from repro.sim.simulator import SimConfig
from repro.traces.qwentrace import TraceConfig, generate


def test_cluster_single_instance_parity_with_prefill_sim():
    """ClusterSim(num_instances=1, round-robin) must reproduce PrefillSim
    exactly — same engine, same event ordering — on the same trace+seed."""
    reqs = generate(TraceConfig(rate=4, duration=40, seed=0))
    single = simulate("flowprefill", reqs)
    cluster = simulate_cluster("flowprefill", reqs, num_instances=1,
                               dispatch="round-robin")
    assert cluster.attainment == single.attainment
    assert cluster.rounds == single.rounds
    assert cluster.preemptions == single.preemptions
    assert cluster.makespan == single.makespan
    t_single = sorted(r.ttft for r in single.requests)
    t_cluster = sorted(r.ttft for r in cluster.requests)
    np.testing.assert_allclose(t_cluster, t_single, rtol=0, atol=0)


def test_every_request_dispatched_exactly_once():
    reqs = generate(TraceConfig(rate=8, duration=30, seed=1))
    res = simulate_cluster("flowprefill", reqs, num_instances=3,
                           dispatch="least-loaded")
    assert sum(res.dispatched) == len(reqs)
    assert all(r.first_token_time is not None for r in res.requests)
    assert all(r.first_token_time >= r.arrival for r in res.requests)


def cluster_goodput(num_instances, policy, burstiness=1.0, seed=3):
    rates = [2 * num_instances, 4 * num_instances, 6 * num_instances,
             8 * num_instances, 12 * num_instances]
    atts = []
    for rate in rates:
        reqs = generate(TraceConfig(rate=rate, duration=30, seed=seed,
                                    burstiness=burstiness))
        atts.append(simulate_cluster(
            "flowprefill", reqs, num_instances=num_instances,
            dispatch=policy).attainment)
    return max_goodput(rates, atts)


def test_goodput_scales_with_instance_count():
    g = {n: cluster_goodput(n, "least-loaded") for n in (1, 2, 4)}
    assert g[1] < g[2] < g[4]
    assert g[2] >= 1.6 * g[1]           # near-linear scaling
    assert g[4] >= 1.6 * g[2]


def test_bursty_load_aware_beats_round_robin():
    """The fig18 acceptance claim: under bursty arrivals, least-loaded and
    slack-aware deflection both beat blind round-robin at cluster scale."""
    rate = 32
    reqs = generate(TraceConfig(rate=rate, duration=40, seed=3,
                                burstiness=3.0))
    att = {pol: simulate_cluster("flowprefill", reqs, num_instances=4,
                                 dispatch=pol).attainment
           for pol in ("round-robin", "least-loaded", "deflection")}
    assert att["least-loaded"] > att["round-robin"] + 0.01
    assert att["deflection"] > att["round-robin"] + 0.01


def test_decode_phase_tpot_accounting():
    reqs = generate(TraceConfig(rate=6, duration=30, seed=2,
                                output_mean=128, tbt_slo=0.05))
    res = simulate_cluster("flowprefill", reqs, num_instances=2,
                           dispatch="least-loaded", decode_instances=2)
    assert res.decoded == len(reqs)
    for r in res.requests:
        assert r.mean_tpot is not None and r.mean_tpot > 0
        assert r.finish_time is not None
        assert r.finish_time >= r.first_token_time
        # can't decode faster than the unbatched analytic step time
        dec = DecodeCostModel(LLAMA3_8B, A800)
        assert r.mean_tpot >= dec.step_time(1, r.num_tokens) * 0.5
    # e2e attainment accounts for the TBT SLO on top of TTFT
    assert res.e2e_attainment <= res.attainment


def test_decode_tbt_slo_binds_under_decode_pressure():
    """With one decode instance absorbing a whole cluster's prefills, decode
    batches grow and TPOT degrades; an aggressive TBT SLO must then fail
    requests that met their TTFT SLO (e2e < TTFT attainment)."""
    reqs = generate(TraceConfig(rate=16, duration=30, seed=5,
                                output_mean=256, tbt_slo=0.011))
    res = simulate_cluster("flowprefill", reqs, num_instances=4,
                           dispatch="least-loaded", decode_instances=1)
    assert res.decoded == len(reqs)
    assert res.e2e_attainment < res.attainment


def test_request_reuse_clears_decode_outcomes():
    """Re-running the same Request objects must not leak the previous run's
    decode outcomes (mean_tpot/finish_time) into e2e accounting: a passing
    first run followed by a decode-less rerun must read as NOT decoded."""
    from dataclasses import replace

    from repro.sim.costmodel import MODEL_TP
    from repro.sim.policies import preset

    reqs = generate(TraceConfig(rate=4, duration=10, seed=7,
                                output_mean=64, tbt_slo=10.0))  # all TBT-pass
    first = simulate_cluster("flowprefill", reqs, num_instances=1,
                             dispatch="round-robin", decode_instances=1)
    assert first.decoded == len(reqs)
    assert first.e2e_attainment == first.attainment > 0
    # same Request list, no decode instances: outcomes must be cleared, and
    # requests that wanted decode but never got it are not e2e-met
    spec = replace(LLAMA3_8B, tp=MODEL_TP["llama3-8b"])
    sim = ClusterSim(PrefillCostModel(spec, A800), preset("flowprefill"),
                     num_instances=1, decode_instances=0)
    second = sim.run(reqs)
    assert all(r.mean_tpot is None and r.finish_time is None
               for r in second.requests)
    assert second.e2e_attainment == 0.0 < second.attainment


def test_decode_cost_model_monotone():
    dec = DecodeCostModel(LLAMA3_8B, A800)
    # llama3-8b bf16 weights ~16 GB
    assert 10e9 <= dec.weight_bytes <= 20e9
    assert dec.step_time(0, 0) == 0.0
    t1 = dec.step_time(1, 1024)
    t8 = dec.step_time(8, 1024)
    t8_long = dec.step_time(8, 8192)
    assert 0 < t1 <= t8 <= t8_long
    # weights dominate small batches: near-flat from B=1 to B=8
    assert t8 < 1.5 * t1


def test_cluster_rejects_zero_instances():
    try:
        ClusterSim(PrefillCostModel(LLAMA3_8B, A800), SimConfig(),
                   num_instances=0)
        raise AssertionError("expected ValueError")
    except ValueError:
        pass
