"""Continuous-batching decode runtime: batched-vs-sequential numerical
parity (B=1 bit-matches the single-stream path; B>1 matches per-stream
replay), continuous join/leave mid-step, preemption-as-eviction resume
state, bounded jit recompiles across the bucket sweep, batched PagedKVCache
I/O parity, and the measured step-time prior."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_tiny_config
from repro.core.predictor import DecodeStepPredictor, MeasuredStepTime
from repro.core.request import Request
from repro.models import init_params
from repro.models.model import decode_step, prefill, supports_ragged_decode
from repro.serving.decode_instance import (DecodeInstance, DecodeJob,
                                           profile_step_times)
from repro.serving.kvcache import PagedKVCache

CFG = dataclasses.replace(get_tiny_config("llama3_8b"),
                          num_layers=2, d_model=128, d_ff=256)
MAX_SEQ = 256


@pytest.fixture(scope="module")
def model():
    params = init_params(CFG, jax.random.PRNGKey(0))
    return params


def _handoff(params, n, seed):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, CFG.vocab_size, (1, n)), jnp.int32)
    logits, cache = prefill(params, CFG, {"tokens": toks}, max_seq=MAX_SEQ)
    return int(jnp.argmax(logits, -1)[0]), \
        {"k": cache["k"], "v": cache["v"], "pos": cache["pos"]}


def _replay(params, first, cache, n_tokens):
    """Sequential single-stream reference: today's dense decode_step loop."""
    tok = jnp.asarray([first], jnp.int32)
    c = dict(cache)
    out = []
    for _ in range(n_tokens):
        logits, c = decode_step(params, CFG, tok, c)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out, c


def _job(first, cache, out_tokens, tbt=100.0):
    req = Request(num_tokens=int(cache["pos"]), slo=100.0, arrival=0.0,
                  output_tokens=out_tokens, tbt_slo=tbt)
    return DecodeJob(request=req, cache=dict(cache), first_token=first)


# --- numerical parity --------------------------------------------------------


def test_b1_path_bitmatches_single_stream_runtime(model):
    """decode_max_batch=1 keeps the original worker: the SAME jitted dense
    decode_step on the job's own cache — final cache and token trajectory
    are bit-equal to a sequential replay."""
    params = model
    first, cache = _handoff(params, 48, seed=0)
    want_tokens, want_cache = _replay(params, first, cache, 5)

    inst = DecodeInstance(params, CFG, decode_tokens=5, decode_max_batch=1)
    try:
        job = _job(first, cache, 5)
        inst.submit(job)
        assert inst.drain(60.0)
    finally:
        inst.shutdown()
    assert job.next_token == want_tokens[-1]
    for key in ("k", "v"):
        assert np.array_equal(np.asarray(job.cache[key]),
                              np.asarray(want_cache[key])), key


def test_batched_matches_per_stream_replay(model):
    """B>1 continuous batch reproduces each stream's sequential greedy
    decode (ragged lengths, shared jitted step, paged KV)."""
    params = model
    streams = [_handoff(params, n, seed=i)
               for i, n in enumerate((32, 48, 80, 100))]
    want = [_replay(params, f, c, 6)[0] for f, c in streams]

    inst = DecodeInstance(params, CFG, decode_tokens=6, decode_max_batch=4,
                          kv_block_size=64)
    jobs = [_job(f, c, 6) for f, c in streams]
    try:
        for j in jobs:
            inst.submit(j)
        assert inst.drain(60.0)
    finally:
        inst.shutdown()
    assert [j.tokens_done for j in jobs] == [6] * 4
    assert [j.next_token for j in jobs] == [w[-1] for w in want]
    assert inst.steps >= 6                    # one jitted step per token
    assert len(inst.tbt_samples) == 4 * 6     # every (stream, token) sampled


def test_continuous_join_and_leave_mid_step(model):
    """A stream submitted while the batch is mid-decode joins at a token
    boundary; earlier-finishing streams leave without disturbing the rest."""
    params = model
    s1, s2, s3 = (_handoff(params, n, seed=10 + i)
                  for i, n in enumerate((32, 48, 64)))
    inst = DecodeInstance(params, CFG, decode_tokens=8, decode_max_batch=4,
                          kv_block_size=64)
    jobs = [_job(s1[0], s1[1], 20), _job(s2[0], s2[1], 4)]
    try:
        for j in jobs:
            inst.submit(j)
        # wait until decoding is underway, then join a third stream
        deadline = time.monotonic() + 30.0
        while inst.steps < 2 and time.monotonic() < deadline:
            time.sleep(0.002)
        assert inst.steps >= 2
        late = _job(s3[0], s3[1], 6)
        jobs.append(late)
        inst.submit(late)
        assert inst.drain(60.0)
    finally:
        inst.shutdown()
    assert [j.tokens_done for j in jobs] == [20, 4, 6]
    assert len(inst.finished) == 3
    # the late stream's decode matches its own sequential replay
    want, _ = _replay(params, s3[0], s3[1], 6)
    assert late.next_token == want[-1]


def test_preemption_is_slot_eviction_with_resume(model):
    """At the slot cap, a tight-TBT arrival displaces the most slack-rich
    resident at a token boundary; the evicted stream keeps progress, KV
    blocks, and next token, resumes later, and still decodes exactly its
    target — matching a sequential replay."""
    params = model
    # ema_alpha=0 pins the calibration scale: early measured steps include
    # jit compiles (seconds), which would inflate t_step until the tight
    # stream ranks as doomed — and doomed streams never preempt
    pred = DecodeStepPredictor(prior=lambda b, c: 1e-4, ema_alpha=0.0)
    loose_s = [_handoff(params, 32, seed=20), _handoff(params, 48, seed=21)]
    tight_s = _handoff(params, 40, seed=22)
    inst = DecodeInstance(params, CFG, decode_tokens=8, decode_max_batch=2,
                          kv_block_size=64, policy="s-edf",
                          step_predictor=pred)
    loose = [_job(f, c, 40, tbt=100.0) for f, c in loose_s]
    # tbt=2.0: far tighter than loose (earlier deadline wins the ranking)
    # yet a 12 s budget no wall-clock hiccup (mid-test jit compiles take
    # ~0.2 s/step in a loaded suite process) can push into doomed territory
    # — a doomed stream ranks below everything and would be evicted itself
    tight = _job(*tight_s, 6, tbt=2.0)
    try:
        for j in loose:
            inst.submit(j)
        deadline = time.monotonic() + 30.0
        while inst.steps < 2 and time.monotonic() < deadline:
            time.sleep(0.002)
        inst.submit(tight)
        assert inst.drain(120.0)
    finally:
        inst.shutdown()
    assert inst.preemptions >= 1
    assert sum(j.request.decode_preemptions for j in loose) >= 1
    assert tight.request.finish_time < max(j.request.finish_time
                                           for j in loose)
    assert [j.tokens_done for j in loose] == [40, 40]
    assert tight.tokens_done == 6
    for j, (f, c) in zip(loose, loose_s):
        want, _ = _replay(params, f, c, 40)
        assert j.next_token == want[-1]       # eviction preserved the stream


def test_jit_recompiles_bounded_by_shape_buckets(model):
    """Sweeping resident populations 1..8 must only ever trace the bucketed
    shapes: compiled-step count <= |batch buckets| x |KV width buckets|."""
    params = model
    inst = DecodeInstance(params, CFG, decode_tokens=2, decode_max_batch=8,
                          kv_block_size=64, batch_buckets=(1, 2, 4, 8))
    try:
        seed = 100
        for n_streams in (1, 2, 3, 5, 7, 8):
            jobs = []
            for _ in range(n_streams):
                # 32/48-token prompts + 2-token targets all allocate ONE
                # 64-token block, so exactly one KV width bucket exists no
                # matter how admissions interleave with in-flight submits
                f, c = _handoff(params, 32 + 16 * (seed % 2), seed)
                seed += 1
                jobs.append(_job(f, c, 2))
                inst.submit(jobs[-1])
            assert inst.drain(120.0)
        n_widths = 1
        assert 0 < inst.compile_cache_size() <= 4 * n_widths
    finally:
        inst.shutdown()


def test_unsupported_family_rejects_batched_decode():
    ssm_cfg = get_tiny_config("mamba2_370m")
    assert not supports_ragged_decode(ssm_cfg)
    with pytest.raises(ValueError, match="decode_max_batch"):
        DecodeInstance(None, ssm_cfg, decode_max_batch=2)


# --- migration out of the pool ----------------------------------------------


@pytest.mark.parametrize("dst_cap", [1, 2])
def test_take_extracts_evicted_pool_resident_stream(model, dst_cap):
    """A stream whose KV lives in the paged pool (evicted resident) must be
    handed off as a dense cache that another instance — batched OR the
    slot-cap-1 dense path — can decode to the same result."""
    params = model
    f, c = _handoff(params, 48, seed=30)
    want, _ = _replay(params, f, c, 6)
    src = DecodeInstance(params, CFG, decode_tokens=6, decode_max_batch=2,
                         kv_block_size=64)
    dst = DecodeInstance(params, CFG, decode_tokens=6,
                         decode_max_batch=dst_cap, kv_block_size=64)
    job = _job(f, c, 6)
    try:
        # stop src's worker first so it cannot re-admit and decode the
        # hand-planted waiting job before take() runs (take needs no worker)
        src.shutdown()
        # ingest by hand: admit into the pool, then evict back to waiting
        with src._cv:
            job.target = 6
            assert src._ingest(job)
            src._waiting.append(job)
        assert job.cache is None              # pool is authoritative now
        taken = src.take([job.request.rid])
        assert len(taken) == 1 and taken[0].cache is not None
        assert src.kv.table(job.request.rid) is None   # blocks freed
        dst.submit(taken[0])
        assert dst.drain(60.0)
    finally:
        src.shutdown()
        dst.shutdown()
    assert job.tokens_done == 6
    assert job.next_token == want[-1]


def test_migrated_midstream_job_resumes_at_correct_position(model):
    """A job preempted mid-decode elsewhere (tokens_done > 0, cache pos =
    prompt + decoded) must resume in a batched instance at the RIGHT kv
    position: base_len + tokens_done == pos, no gap and no overrun."""
    params = model
    f, c = _handoff(params, 48, seed=40)
    want, _ = _replay(params, f, c, 8)
    # replay the first 3 tokens to build the mid-stream handoff state
    done, mid_cache = _replay(params, f, c, 3)
    req = Request(num_tokens=48, slo=100.0, arrival=0.0, output_tokens=8,
                  tbt_slo=100.0)
    job = DecodeJob(request=req, first_token=f, tokens_done=3,
                    next_token=done[-1],
                    cache={"k": mid_cache["k"], "v": mid_cache["v"],
                           "pos": mid_cache["pos"]})
    inst = DecodeInstance(params, CFG, decode_tokens=8, decode_max_batch=2,
                          kv_block_size=64)
    try:
        inst.submit(job)
        assert inst.drain(60.0)
    finally:
        inst.shutdown()
    assert job.tokens_done == 8
    assert job.next_token == want[-1]


def test_no_livelock_when_pool_cannot_fit_selected_streams(model):
    """No-resident deadlock guard: if every selected stream fails pool
    allocation while an evicted stream's blocks sit idle, the instance must
    force progress (grow for the top candidate) instead of spinning — all
    streams finish."""
    params = model
    pred = DecodeStepPredictor(prior=lambda b, c: 1e-4, ema_alpha=0.0)
    inst = DecodeInstance(params, CFG, decode_tokens=4, decode_max_batch=2,
                          kv_block_size=32, policy="s-edf",
                          step_predictor=pred)
    small = _handoff(params, 32, seed=50)      # sizes the pool small
    big = [_handoff(params, 250, seed=51), _handoff(params, 250, seed=52)]
    loose = _job(*small, 4, tbt=100.0)
    tights = [_job(fc[0], fc[1], 50, tbt=0.05) for fc in big]
    try:
        inst.submit(loose)
        deadline = time.monotonic() + 30.0
        while inst.steps < 1 and time.monotonic() < deadline:
            time.sleep(0.002)
        for t in tights:                       # both outrank + outsize the pool
            inst.submit(t)
        assert inst.drain(120.0), "instance livelocked instead of growing"
    finally:
        inst.shutdown()
    assert loose.tokens_done == 4
    assert [t.tokens_done for t in tights] == [50, 50]


def test_oversized_stream_not_starved_while_pool_busy(model):
    """A stream whose KV footprint exceeds the WHOLE pool must trigger a
    grow even while other streams are resident (waiting for completions can
    never free enough blocks for it) — no starvation under continuous
    load."""
    params = model
    inst = DecodeInstance(params, CFG, decode_tokens=4, decode_max_batch=2,
                          kv_block_size=32)
    small = _handoff(params, 32, seed=60)      # sizes the pool small
    big = _handoff(params, 250, seed=61)       # needs more than the pool
    resident = _job(*small, 30)                # long-lived resident
    oversized = _job(*big, 4)
    try:
        inst.submit(resident)
        deadline = time.monotonic() + 30.0
        while inst.steps < 1 and time.monotonic() < deadline:
            time.sleep(0.002)
        inst.submit(oversized)
        assert inst.drain(120.0), "oversized stream starved"
    finally:
        inst.shutdown()
    assert resident.tokens_done == 30
    assert oversized.tokens_done == 4


# --- batched PagedKVCache I/O ------------------------------------------------


def test_write_tokens_matches_scalar_write():
    cache_a = PagedKVCache(2, 16, 4, 2, 8)
    cache_b = PagedKVCache(2, 16, 4, 2, 8)
    rng = np.random.default_rng(0)
    for sid, n in ((0, 6), (1, 3)):
        cache_a.allocate(sid, 12)
        cache_b.allocate(sid, 12)
    k = jnp.asarray(rng.standard_normal((2, 2, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, 2, 8)), jnp.float32)
    positions = [5, 2]
    for i, sid in enumerate((0, 1)):
        cache_a.write(sid, positions[i], k[:, i], v[:, i])
    cache_b.write_tokens([0, 1], positions, k, v)
    assert np.array_equal(np.asarray(cache_a.k_pool),
                          np.asarray(cache_b.k_pool))
    assert np.array_equal(np.asarray(cache_a.v_pool),
                          np.asarray(cache_b.v_pool))
    assert cache_b.table(0).length == 6 and cache_b.table(1).length == 3


def test_gather_batch_matches_per_seq_gather():
    cache = PagedKVCache(2, 32, 4, 2, 8)
    rng = np.random.default_rng(1)
    lens = {0: 10, 1: 5, 2: 7}
    for sid, n in lens.items():
        cache.allocate(sid, n)
        k = jnp.asarray(rng.standard_normal((2, n, 2, 8)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, n, 2, 8)), jnp.float32)
        cache.write_prompt(sid, k, v)
    kb, vb, out_lens = cache.gather_batch([0, 1, 2], width=4)
    assert kb.shape == (2, 3, 16, 2, 8)
    assert out_lens.tolist() == [10, 5, 7]
    for i, sid in enumerate((0, 1, 2)):
        ks, vs, ln = cache.gather(sid)
        assert ln == lens[sid]
        assert np.array_equal(np.asarray(kb)[:, i, :ln],
                              np.asarray(ks)[:, :ln])
        assert np.array_equal(np.asarray(vb)[:, i, :ln],
                              np.asarray(vs)[:, :ln])


def test_pool_grow_preserves_data_and_free_accounting():
    cache = PagedKVCache(1, 4, 4, 1, 8)
    cache.allocate(0, 8)
    k = jnp.ones((1, 8, 1, 8))
    cache.write_prompt(0, k, k)
    free_before = cache.free_blocks
    cache.grow(4)
    assert cache.num_blocks == 8
    assert cache.free_blocks == free_before + 4
    ks, _, ln = cache.gather(0)
    assert ln == 8 and np.asarray(ks)[:, :8].sum() == 8 * 8


# --- measured step-time prior ------------------------------------------------


def test_measured_step_time_recovers_synthetic_surface():
    truth = lambda b, c: 2e-3 + 4e-4 * b + 1e-7 * b * c    # noqa: E731
    samples = [(b, c, truth(b, c))
               for b in (1, 2, 4, 8) for c in (128.0, 512.0, 2048.0)]
    fit = MeasuredStepTime.fit(samples)
    assert fit.rel_err(samples) < 1e-6
    pred = DecodeStepPredictor.from_profile(samples)
    assert pred.step_time(3, 300.0) == pytest.approx(truth(3, 300.0),
                                                     rel=1e-6)
    # EMA calibration still layers on top of the measured prior
    pred.observe(3, 300.0, 2.0 * truth(3, 300.0))
    assert pred.scale > 1.0


def test_measured_step_time_stays_monotone_on_noisy_profile():
    """A noisy profile where larger batches happened to measure faster must
    NOT fit a surface that decreases with B or ctx — negative slope terms
    are clamped at fit time (a bigger-is-faster latency model would invert
    S-EDF slack ranking)."""
    noisy = [(1, 128.0, 5e-3), (2, 128.0, 4e-3), (4, 128.0, 3e-3),
             (8, 128.0, 2.5e-3)]
    fit = MeasuredStepTime.fit(noisy)
    assert fit.c1 >= 0.0 and fit.c2 >= 0.0
    for ctx in (64.0, 512.0):
        ts = [fit(b, ctx) for b in (1, 2, 4, 8)]
        assert ts == sorted(ts)
    assert fit(4, 1024.0) >= fit(4, 64.0)


def test_profile_step_times_feeds_predictor(model):
    samples = profile_step_times(model, CFG, batch_sizes=(1, 2),
                                 ctx=64, decode_tokens=3, warmup=1,
                                 kv_block_size=64)
    assert [b for b, _, _ in samples] == [1, 2]
    assert all(t > 0 for _, _, t in samples)
    pred = DecodeStepPredictor.from_profile(samples)
    assert pred.step_time(2, 64.0) > 0
