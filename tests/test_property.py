"""Hypothesis property tests on system invariants: S-EDF ordering, SLO-aware
batching budget/deadline safety, predictor monotonicity-ish sanity, paged KV
cache allocator conservation (plain AND refcounted prefix-sharing modes),
tiered-cache conservation (HBM/host/disk residency + in-flight promotions),
cluster-churn exactly-once accounting under random fault interleavings, and
goodput-metric monotonicity."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_faults import run_sim_fault_case  # noqa: E402
from test_tiered_kv import run_tier_property_case  # noqa: E402

from repro.core import Request, SchedulerCore, TTFTPredictor, max_goodput
from repro.core.prefixcache import PrefixBlockManager, chain_extend
from repro.core.scheduler import slo_aware_batching
from repro.serving.kvcache import PagedKVCache

PRED = TTFTPredictor(coeffs=np.array([2e-4, 0.0]), floor=0.0)


def reqs_strategy(n_max=12):
    one = st.builds(
        Request,
        num_tokens=st.integers(1, 40000),
        slo=st.floats(0.01, 30.0, allow_nan=False),
        arrival=st.floats(0.0, 100.0, allow_nan=False),
    )
    return st.lists(one, min_size=1, max_size=n_max)


# --- priority / ranking ------------------------------------------------------

@given(reqs_strategy(), st.floats(0.0, 120.0))
@settings(max_examples=60, deadline=None)
def test_rank_is_total_order_feasible_first(requests, now):
    core = SchedulerCore(predictor=PRED)
    ranked = core.rank(requests, now)
    assert len(ranked) == len(requests)
    assert {r.rid for r in ranked} == {r.rid for r in requests}
    prios = [core.priority(r, now) for r in ranked]
    assert all(a >= b - 1e-12 for a, b in zip(prios, prios[1:]))
    # every feasible (positive-slack) request ranks above every doomed one
    feas = [p >= 0 for p in prios]
    if True in feas and False in feas:
        assert feas.index(False) > max(i for i, f in enumerate(feas) if f)


# --- batching ---------------------------------------------------------------

@given(reqs_strategy(), st.integers(64, 100000), st.floats(0.0, 50.0))
@settings(max_examples=60, deadline=None)
def test_batching_invariants(requests, budget, now):
    H, cands = requests[0], requests[1:]
    h_tokens = H.num_tokens
    Hb, batch = slo_aware_batching(H, cands, budget, now, PRED.predict)
    total = sum(r.num_tokens for r in batch)
    # budget respected whenever anything was admitted beyond H
    if len(batch) > 1:
        assert total < budget
        # H's remaining time covers the predicted aggregate latency
        assert H.deadline - now > PRED.predict(total)
    assert batch[0].rid == H.rid
    assert Hb.batch_tokens == total
    assert len({r.rid for r in batch}) == len(batch)   # no duplicates
    assert total >= h_tokens


# --- predictor ----------------------------------------------------------------

@given(st.lists(st.integers(64, 32768), min_size=4, max_size=20, unique=True))
@settings(max_examples=30, deadline=None)
def test_predictor_fit_nonnegative(tokens):
    tokens = sorted(tokens)
    lat = [1e-6 * t + 1e-10 * t * t for t in tokens]
    p = TTFTPredictor.fit(tokens, lat, degree=2)
    for t in tokens:
        assert p.predict(t) >= 0.0
    # interpolation error small on the fitted (noise-free quadratic) profile
    mid = tokens[len(tokens) // 2]
    assert abs(p.predict(mid) - (1e-6 * mid + 1e-10 * mid * mid)) < 1e-3


# --- paged KV cache ------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(1, 300), st.booleans()),
                min_size=1, max_size=30))
@settings(max_examples=40, deadline=None)
def test_kvcache_allocator_conservation(ops):
    cache = PagedKVCache(num_layers=2, num_blocks=64, block_size=16,
                         num_kv_heads=2, head_dim=8)
    total = cache.num_blocks
    live = {}
    sid = 0
    for tokens, do_free in ops:
        need = cache.blocks_needed(tokens)
        if need <= cache.free_blocks:
            t = cache.allocate(sid, tokens)
            live[sid] = t
            sid += 1
        if do_free and live:
            k = next(iter(live))
            cache.free(k)
            del live[k]
        # conservation: free + live == total, and no block in two tables
        used = [b for t in live.values() for b in t.blocks]
        assert len(used) == len(set(used))
        assert cache.free_blocks + len(used) == total


def test_kvcache_data_roundtrip():
    import jax.numpy as jnp
    cache = PagedKVCache(num_layers=2, num_blocks=8, block_size=4,
                         num_kv_heads=2, head_dim=4)
    cache.allocate(0, 10)
    k = jnp.arange(2 * 10 * 2 * 4, dtype=jnp.float32).reshape(2, 10, 2, 4)
    v = k + 1000
    cache.write_prompt(0, k, v)
    kg, vg, length = cache.gather(0)
    assert length == 10
    np.testing.assert_array_equal(np.asarray(kg[:, :10]), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(vg[:, :10]), np.asarray(v))
    # single-token append at position 10
    cache.extend(0, 1)
    k1 = jnp.full((2, 2, 4), 7.0)
    cache.write(0, 10, k1, k1 * 2)
    kg, vg, length = cache.gather(0)
    assert length == 11
    np.testing.assert_array_equal(np.asarray(kg[:, 10]), np.asarray(k1))


# --- prefix-sharing block manager -------------------------------------------

_CHAINS = [chain_extend((), range(8), salt=s) for s in range(4)]
# chains 4..5 diverge from chain 0 after 3 blocks (shared prefix, unique tail)
_CHAINS += [chain_extend(_CHAINS[0][:3], range(5), salt=40 + s)
            for s in range(2)]

_ops = st.lists(
    st.tuples(st.sampled_from(["acquire", "release", "commit", "cow"]),
              st.integers(0, len(_CHAINS) - 1), st.integers(1, 8)),
    min_size=1, max_size=40)


@given(_ops)
@settings(max_examples=60, deadline=None)
def test_prefix_manager_conservation_under_share_free_interleavings(ops):
    """After EVERY operation: free + live + cached == num_blocks, the three
    sets disjoint, refcounts exactly matching held references — under
    arbitrary share/free/commit/copy-on-divergence interleavings, including
    rolled-back allocation failures. Eviction never touches a held block
    (check() would catch a pinned block leaving the live set)."""
    mgr = PrefixBlockManager(16)
    held = {}
    sid = 0
    for kind, chain, nblocks in ops:
        keys = _CHAINS[chain][:nblocks]
        if kind == "acquire":
            try:
                mgr.acquire(sid, keys, nblocks)
                held[sid] = (keys, nblocks)
                sid += 1
            except MemoryError:
                pass                      # full: the rollback must be clean
        elif kind == "release" and held:
            k = next(iter(held))
            mgr.register(k, held[k][0])   # share-then-free: park in LRU
            mgr.release(k)
            del held[k]
        elif kind == "commit" and held:
            k = next(iter(held))
            mgr.commit(k, held[k][0])
            del held[k]
        elif kind == "cow" and held:
            k = next(iter(held))
            try:
                mgr.make_private(k, held[k][1] - 1)
            except MemoryError:
                pass
        mgr.check()
    for k in list(held):
        mgr.release(k)
    mgr.check()
    assert mgr.live_blocks == 0           # every reference dropped


@given(st.lists(st.tuples(st.integers(0, len(_CHAINS) - 1),
                          st.integers(1, 6)),
                min_size=1, max_size=25))
@settings(max_examples=60, deadline=None)
def test_prefix_manager_eviction_never_drops_pinned_blocks(allocs):
    """Under permanent capacity pressure, LRU eviction reclaims only
    refcount-0 blocks: every chain still held keeps its exact blocks, and
    no two diverged suffixes ever alias a block."""
    mgr = PrefixBlockManager(12)
    pinned = {}
    sid = 0
    for chain, nblocks in allocs:
        keys = _CHAINS[chain][:nblocks]
        try:
            hit = mgr.acquire(sid, keys, nblocks)
        except MemoryError:
            continue
        blocks = mgr.blocks_of(sid)
        # beyond the cached hit, fresh blocks are private to this chain
        fresh = set(blocks[hit:])
        for s, (other, oh) in pinned.items():
            assert not fresh & set(other[oh:]), \
                "two diverged suffixes share a block"
        if sid % 2 == 0:
            pinned[sid] = (blocks, hit)
        else:
            mgr.commit(sid, keys)         # becomes evictable
        sid += 1
        mgr.check()
        for s, (blocks_, _) in pinned.items():
            assert mgr.blocks_of(s) == blocks_, "pinned chain mutated"


# --- tiered block manager ----------------------------------------------------

@given(st.integers(0, 2**32 - 1))
@settings(max_examples=60, deadline=None)
def test_tiered_manager_conservation(seed):
    """Tier-adjusted conservation under random op interleavings: free +
    live + cached + in_flight == num_blocks after EVERY op, chain keys
    exclusive across warm/in-flight/host/disk, cold tiers within capacity,
    and a pinned hit prefix never demoted. Delegates to the scenario shared
    with tests/test_tiered_kv.py (which drives it through fixed seeds when
    hypothesis is unavailable) so hypothesis explores the same invariants
    with free rein over the seed space."""
    run_tier_property_case(np.random.default_rng(seed))


# --- cluster churn / fault recovery ------------------------------------------

@given(st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_cluster_fault_interleavings_exactly_once(seed):
    """Random fault interleavings (crash/hang/slowdown/spot/kv_link over
    random instants, instances, and outage lengths) against random traces:
    every request reaches EXACTLY one terminal state, counters conserve
    (served + lost + shed == submitted), losses only past the retry budget,
    and the run always terminates. Delegates to the scenario shared with
    tests/test_faults.py (which drives it through fixed seeds when
    hypothesis is unavailable, and mirrors the same invariants against the
    threaded runtime)."""
    run_sim_fault_case(np.random.default_rng(seed))


# --- goodput metric -------------------------------------------------------------

@given(st.lists(st.floats(0.0, 1.0), min_size=3, max_size=12))
@settings(max_examples=40, deadline=None)
def test_max_goodput_bounds(atts):
    rates = list(np.linspace(1, 10, len(atts)))
    g = max_goodput(rates, atts, target=0.9)
    assert 0.0 <= g <= 10.0
    # if all attainments pass, goodput is the max rate
    if min(atts) >= 0.9:
        assert g == pytest.approx(10.0)
