"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step + one prefill→decode roundtrip on CPU, asserting shapes and
no NaNs. Full configs are only exercised by the dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_tiny_config
from repro.models import (
    decode_step, forward, init_params, param_count, prefill,
)

B, S = 2, 24


def make_batch(cfg, rng):
    r1, r2, r3 = jax.random.split(rng, 3)
    batch = {
        "tokens": jax.random.randint(r1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(r2, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            r3, (B, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            r3, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg = get_tiny_config(arch)
    rng = jax.random.PRNGKey(0)
    params = init_params(cfg, rng)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch):
    cfg = get_tiny_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        logits = forward(p, cfg, batch)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
        return -jnp.mean(ll)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0

    # one SGD step reduces loss on the same batch
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype), params, grads)
    loss2 = jax.jit(loss_fn)(params2)
    assert float(loss2) < float(loss), f"{arch}: loss did not decrease"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_roundtrip(arch):
    cfg = get_tiny_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    max_seq = S + 8

    logits, cache = jax.jit(
        lambda p, b: prefill(p, cfg, b, max_seq=max_seq, cache_dtype=jnp.float32)
    )(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dstep = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    for _ in range(3):
        logits, cache = dstep(params, tok, cache)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite decode logits"
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Prefill + iterative decode logits must match a single full forward pass
    (teacher forcing) — validates cache correctness per family."""
    if arch == "whisper_large_v3":
        pytest.skip("audio prefill starts decoder empty; covered separately")
    cfg = get_tiny_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    n_extra = 4
    full_tokens = jnp.concatenate(
        [batch["tokens"],
         jax.random.randint(jax.random.PRNGKey(2), (B, n_extra), 0, cfg.vocab_size)],
        axis=1)

    fwd_batch = dict(batch, tokens=full_tokens)
    all_logits = forward(params, cfg, fwd_batch)           # (B, S+n, V)

    _, cache = prefill(params, cfg, batch, max_seq=S + n_extra,
                       cache_dtype=jnp.float32)
    for i in range(n_extra):
        step_logits, cache = decode_step(params, cfg, full_tokens[:, S + i], cache)
        ref = all_logits[:, S + i]
        np.testing.assert_allclose(
            np.asarray(step_logits, np.float32), np.asarray(ref, np.float32),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode step {i} diverges from forward")


def test_full_config_param_counts():
    """Analytic param counts of the FULL configs are in the right ballpark
    (validates the configs transcribe the published architectures)."""
    expected = {
        "internvl2_76b": (60e9, 90e9),
        "recurrentgemma_9b": (7e9, 12e9),
        "llama4_maverick_400b": (350e9, 450e9),
        "granite_moe_3b": (2e9, 4.5e9),
        "llama3_2_1b": (1e9, 1.8e9),
        "qwen2_5_3b": (2.5e9, 4e9),
        "qwen2_1_5b": (1.2e9, 2e9),
        "minitron_4b": (3.5e9, 6e9),
        "mamba2_370m": (0.25e9, 0.5e9),
        "whisper_large_v3": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: param count {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"
