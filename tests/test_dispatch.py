"""Dispatch-policy unit tests + the no-duplicated-policy-logic contract:
`Proxy` (real runtime) and `ClusterSim` (simulator) consume the same policy
objects from repro.core.dispatch."""
import numpy as np

from repro.core.dispatch import (DISPATCH_POLICIES, CapacityWeightedDispatch,
                                 DecodeAwareDispatch, DeflectionDispatch,
                                 InstanceLoad, LeastLoadedDispatch,
                                 RoundRobinDispatch, competing_tokens,
                                 drain_time, make_dispatch, predicted_ttft)
from repro.core.predictor import TTFTPredictor
from repro.core.request import Request

PRED = TTFTPredictor(coeffs=np.array([1e-4, 0.0]), floor=0.0)  # 0.1ms/token


def loads(*queued):
    return [InstanceLoad(instance_id=i, queued_tokens=q)
            for i, q in enumerate(queued)]


def req(tokens=100, slo=1.0, arrival=0.0):
    return Request(num_tokens=tokens, slo=slo, arrival=arrival)


def test_round_robin_cycles():
    pol = RoundRobinDispatch()
    picks = [pol.select(req(), loads(0, 0, 0), 0.0) for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_least_loaded_picks_min_predicted_queue():
    pol = LeastLoadedDispatch(PRED)
    assert pol.select(req(), loads(5000, 100, 9000), 0.0) == 1
    # ties break deterministically on instance id
    assert pol.select(req(), loads(500, 500, 500), 0.0) == 0


def test_least_loaded_without_predictor_uses_tokens():
    pol = LeastLoadedDispatch(predictor=None)
    assert pol.select(req(), loads(300, 200, 250), 0.0) == 1


def test_predicted_ttft_includes_newcomer():
    ld = InstanceLoad(instance_id=0, queued_tokens=900)
    assert predicted_ttft(req(tokens=100), ld, PRED) == PRED.predict(1000)


def test_competing_tokens_filters_later_deadlines_and_doomed():
    cand = req(tokens=100, slo=1.0)                    # deadline 1.0
    items = [
        (200.0, 0.5),       # earlier deadline, feasible -> counts
        (300.0, 2.0),       # later deadline -> S-EDF runs it after us
        (5000.0, 0.4),      # earlier deadline but doomed (0.5s predicted
                            # latency > 0.4s slack) -> ranks below any
                            # feasible request
    ]
    assert competing_tokens(items, cand, 0.0, PRED.predict) == 200.0
    # without a predictor only the deadline filter applies
    assert competing_tokens(items, cand, 0.0, None) == 5200.0


def test_deflection_keeps_feasible_primary():
    pol = DeflectionDispatch(PRED, slack_margin=1.0)
    # primary (instance 0) predicted TTFT 0.02s << 1s slack: stays put even
    # though instance 1 is emptier
    assert pol.select(req(tokens=100, slo=1.0), loads(100, 0), 0.0) == 0


def test_deflection_deflects_overloaded_primary():
    pol = DeflectionDispatch(PRED, slack_margin=1.0)
    # primary would blow the newcomer's 0.5s slack (predicted ~1s), deflect
    # to the feasible instance
    assert pol.select(req(tokens=100, slo=0.5), loads(10000, 100), 0.0) == 1


def test_deflection_falls_back_to_least_predicted():
    pol = DeflectionDispatch(PRED, slack_margin=1.0)
    # nobody feasible: take the least predicted TTFT
    assert pol.select(req(tokens=100, slo=0.1), loads(9000, 6000, 8000),
                      0.0) == 1


def test_capacity_weighted_prefers_fast_instance():
    pol = CapacityWeightedDispatch()
    # same 1000-token backlog everywhere: the 2x-capacity instance drains it
    # in half the time and wins
    lds = [InstanceLoad(instance_id=0, queued_tokens=1000, capacity=1000.0),
           InstanceLoad(instance_id=1, queued_tokens=1000, capacity=2000.0)]
    assert pol.select(req(tokens=100), lds, 0.0) == 1
    # the fast instance keeps winning until its backlog costs more wall time
    lds = [InstanceLoad(instance_id=0, queued_tokens=1000, capacity=1000.0),
           InstanceLoad(instance_id=1, queued_tokens=3000, capacity=2000.0)]
    assert pol.select(req(tokens=100), lds, 0.0) == 0
    # uniform capacities degrade to raw-token JSQ with id tie-break
    lds = loads(500, 500, 200)
    assert pol.select(req(), lds, 0.0) == 2


def test_drain_time_normalizes_by_capacity():
    ld = InstanceLoad(instance_id=0, queued_tokens=900, capacity=500.0)
    assert drain_time(req(tokens=100), ld) == 1000 / 500.0
    # capacity 1.0 (unknown) -> raw tokens
    assert drain_time(req(tokens=100), loads(900)[0]) == 1000.0


def test_decode_aware_penalizes_saturated_decode():
    pol = DecodeAwareDispatch(knee=0.85, penalty=8.0)
    # equal prefill drain, but instance 0's decode sits past the TBT knee
    lds = [InstanceLoad(instance_id=0, queued_tokens=500, capacity=1000.0,
                        decode_pressure=1.2),
           InstanceLoad(instance_id=1, queued_tokens=500, capacity=1000.0,
                        decode_pressure=0.3)]
    assert pol.select(req(tokens=100), lds, 0.0) == 1
    # below the knee the policy IS capacity-weighted JSQ (id tie-break)
    lds = [InstanceLoad(instance_id=0, queued_tokens=500, capacity=1000.0,
                        decode_pressure=0.5),
           InstanceLoad(instance_id=1, queued_tokens=500, capacity=1000.0,
                        decode_pressure=0.84)]
    assert pol.select(req(tokens=100), lds, 0.0) == 0
    # saturated decode still loses to a hugely backlogged prefill queue
    lds = [InstanceLoad(instance_id=0, queued_tokens=100, capacity=1000.0,
                        decode_pressure=1.0),
           InstanceLoad(instance_id=1, queued_tokens=50000, capacity=1000.0,
                        decode_pressure=0.0)]
    assert pol.select(req(tokens=100), lds, 0.0) == 0


def test_make_dispatch_registry_and_passthrough():
    assert set(DISPATCH_POLICIES) == {"round-robin", "least-loaded",
                                      "deflection", "capacity-weighted",
                                      "decode-aware", "prefix-affinity"}
    for name in DISPATCH_POLICIES:
        pol = make_dispatch(name, PRED)
        assert pol.name == name and pol.predictor is PRED
    ready = LeastLoadedDispatch()
    assert make_dispatch(ready, PRED) is ready
    assert ready.predictor is PRED                      # adopted
    try:
        make_dispatch("nope")
        raise AssertionError("expected ValueError")
    except ValueError:
        pass


# --- shared-policy contract --------------------------------------------------

class _StubStats:
    mean = 0.0


class _StubInstance:
    """Duck-typed PrefillInstance: records submissions, never executes."""

    def __init__(self):
        self.submitted = []
        self.on_prefill_done = None
        self.scheduling_rounds = 0
        self.blocking_stats = _StubStats()

    def submit_request(self, request, tokens):
        self.submitted.append(request)

    def drain(self, timeout=0.0):
        return True

    def shutdown(self):
        pass


def test_proxy_consumes_shared_policy_object():
    from repro.serving.proxy import Proxy

    policy = LeastLoadedDispatch(PRED)
    stubs = [_StubInstance() for _ in range(3)]
    proxy = Proxy(stubs, dispatch=policy)
    assert proxy.dispatch is policy                    # the very same object
    # all outstanding work piles on the chosen instance (stubs never finish),
    # so JSQ spreads strict same-deadline requests across instances
    t0 = proxy.clock()
    for i in range(6):
        proxy.submit(Request(num_tokens=500, slo=1e9, arrival=t0),
                     np.zeros(4, np.int32))
    assert sorted(len(s.submitted) for s in stubs) == [2, 2, 2]
    assert proxy.report()["dispatch_policy"] == "least-loaded"
    assert proxy.report()["dispatched_by_instance"] == \
        [len(s.submitted) for s in stubs]


def test_cluster_sim_consumes_shared_policy_object():
    from repro.sim.cluster import ClusterSim
    from repro.sim.costmodel import A800, LLAMA3_8B, PrefillCostModel
    from repro.sim.simulator import SimConfig

    policy = DeflectionDispatch()
    sim = ClusterSim(PrefillCostModel(LLAMA3_8B, A800), SimConfig(),
                     num_instances=2, dispatch=policy)
    assert sim.policy is policy
    assert policy.predictor is sim.predictor            # adopted on wiring


def test_proxy_round_robin_default_unchanged():
    from repro.serving.proxy import Proxy

    stubs = [_StubInstance() for _ in range(2)]
    proxy = Proxy(stubs)
    for i in range(4):
        proxy.submit(Request(num_tokens=8, slo=1.0), np.zeros(4, np.int32))
    assert [len(s.submitted) for s in stubs] == [2, 2]
