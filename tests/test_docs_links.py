"""The committed docs tree must stay navigable: the CI link checker
(tools/check_links.py) passes on README.md + docs/, and trips on a broken
relative link (so the lint step actually guards something)."""
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_links  # noqa: E402


def test_committed_docs_have_no_broken_links(capsys):
    assert check_links.main([]) == 0
    out = capsys.readouterr().out
    assert "all relative links resolve" in out


def test_docs_tree_exists_and_is_cross_linked():
    docs = os.path.join(REPO, "docs")
    for name in ("ARCHITECTURE.md", "SCHEDULING.md", "BENCHMARKS.md"):
        assert os.path.exists(os.path.join(docs, name)), name
    readme = open(os.path.join(REPO, "README.md")).read()
    for name in ("docs/ARCHITECTURE.md", "docs/SCHEDULING.md",
                 "docs/BENCHMARKS.md"):
        assert name in readme, f"README must link {name}"


def test_checker_trips_on_broken_link(tmp_path):
    md = tmp_path / "broken.md"
    md.write_text("see [missing](does/not/exist.md) and "
                  "[ok](https://example.com) and [anchor](#here)\n")
    # the tmp file lives outside the repo root, so point REPO at tmp_path to
    # make its links verifiable
    old = check_links.REPO
    check_links.REPO = str(tmp_path)
    try:
        assert check_links.main([str(md)]) == 1
        md.write_text("only [ok](https://example.com) here\n")
        assert check_links.main([str(md)]) == 0
    finally:
        check_links.REPO = old


def test_checker_skips_fenced_code_blocks(tmp_path):
    md = tmp_path / "fenced.md"
    md.write_text("```\n[not a link](nope.md)\n```\n")
    old = check_links.REPO
    check_links.REPO = str(tmp_path)
    try:
        assert check_links.main([str(md)]) == 0
    finally:
        check_links.REPO = old
