"""Sharding validation on a small forced-device mesh (subprocess so the main
test process keeps its single real device). Exercises the same lower+compile
path as the production dry-run for one representative arch per family x all
four shapes, plus the HLO collective parser."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.collectives import collective_bytes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.configs.base import SHAPES, get_tiny_config, shape_applicable
    from repro.distributed import sharding as shd
    from repro.launch.dryrun import cost_dict, lower_cell
    import dataclasses

    arch, shape_name, multi_pod = sys.argv[1], sys.argv[2], sys.argv[3] == "1"
    cfg = get_tiny_config(arch)
    # pad dims so a 2-way model axis divides head counts etc.
    shape = dataclasses.replace(SHAPES[shape_name], global_batch=4,
                                seq_len=min(SHAPES[shape_name].seq_len, 64))
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        print(json.dumps({"status": "skipped", "reason": why}))
        sys.exit(0)
    if multi_pod:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    else:
        mesh = jax.make_mesh((2, 2), ("data", "model"))
    rules = (shd.train_rules(multi_pod=multi_pod) if shape.kind == "train"
             else shd.serve_rules(multi_pod=multi_pod))
    with mesh, shd.use_sharding(mesh, rules):
        lowered = lower_cell(cfg, shape, mesh, rules)
        compiled = lowered.compile()
        cost = cost_dict(compiled)
        hlo_len = len(compiled.as_text())
    print(json.dumps({"status": "ok", "flops": float(cost.get("flops", 0)),
                      "hlo_len": hlo_len}))
""")

FAMILY_REPS = ["llama3_2_1b", "qwen3_30b_a3b", "mamba2_370m",
               "recurrentgemma_9b", "whisper_large_v3", "internvl2_76b"]

# lower+compile in subprocesses: minutes of XLA work — kept out of the CI
# fast job (run with `-m slow`; test_collective_parser below stays fast)
slow = pytest.mark.slow


def run_cell(arch, shape, multi_pod):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, shape, "1" if multi_pod else "0"],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, f"{arch}/{shape}: {out.stderr[-2000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@slow
@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_train_cell_lowers_small_mesh(arch):
    r = run_cell(arch, "train_4k", multi_pod=False)
    assert r["status"] == "ok" and r["flops"] > 0


@slow
@pytest.mark.parametrize("shape", ["prefill_32k", "decode_32k", "long_500k"])
def test_serve_cells_lower_small_mesh(shape):
    for arch in ("llama3_2_1b", "mamba2_370m"):
        r = run_cell(arch, shape, multi_pod=False)
        if r["status"] == "skipped":
            assert shape == "long_500k" and arch == "llama3_2_1b"
        else:
            assert r["status"] == "ok"


@slow
def test_multi_pod_axis_shards():
    r = run_cell("llama3_2_1b", "train_4k", multi_pod=True)
    assert r["status"] == "ok"


def test_collective_parser():
    hlo = """
    %all-reduce.7 = bf16[16,128]{1,0} all-reduce(bf16[16,128]{1,0} %x), replica_groups={}
    %ag = f32[64]{0} all-gather(f32[16]{0} %y), dimensions={0}
    %rs = f32[16]{0} reduce-scatter(f32[64]{0} %z), dimensions={0}
    %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8]{1,0} %w)
    %add.1 = f32[64]{0} add(f32[64]{0} %a, f32[64]{0} %b)
    """
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 16 * 128 * 2
    assert got["all-gather"] == 16 * 4
    assert got["reduce-scatter"] == 64 * 4
    assert got["collective-permute"] == 8 * 8 * 2
    assert "add" not in got
