"""The CI benchmark-regression gate must actually trip: compare.py exits
nonzero on a synthetically degraded BENCH json, passes on identical/improved
results, and run.py --only rejects unknown figure names instead of silently
running nothing (which would green-wash a CI typo)."""
import json

import pytest

from benchmarks import run as bench_run
from benchmarks.compare import is_gated, is_gated_lower, main as compare_main


def write_bench(path, bench, metrics):
    with open(path / f"BENCH_{bench}.json", "w") as f:
        json.dump({"bench": bench, "elapsed_s": 1.0, "metrics": metrics}, f)


BASE = {
    "fig9/llama3-8b/flowprefill/goodput_req_s": 6.21,
    "fig9/llama3-8b/flowprefill_vs_distserve": 3.09,
    "fig9/_elapsed_s": 12.0,                 # never gated
}


@pytest.fixture
def dirs(tmp_path):
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir()
    fresh.mkdir()
    write_bench(base, "fig9", BASE)
    return base, fresh


def test_gate_passes_on_identical_and_improved(dirs):
    base, fresh = dirs
    write_bench(fresh, "fig9", BASE)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0
    better = dict(BASE, **{"fig9/llama3-8b/flowprefill/goodput_req_s": 7.5})
    write_bench(fresh, "fig9", better)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0


def test_gate_trips_on_degraded_goodput(dirs):
    """The acceptance check: a synthetically degraded result (goodput -20%,
    beyond the -10% tolerance) must exit nonzero."""
    base, fresh = dirs
    degraded = dict(BASE, **{"fig9/llama3-8b/flowprefill/goodput_req_s": 4.9})
    write_bench(fresh, "fig9", degraded)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # ...but a -5% wobble inside tolerance passes
    wobble = dict(BASE, **{"fig9/llama3-8b/flowprefill/goodput_req_s": 5.9})
    write_bench(fresh, "fig9", wobble)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0
    # tolerance is configurable: -5% trips a -2% gate
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh),
                         "--tolerance", "0.02"]) == 1


def test_gate_trips_on_missing_bench_or_metric(dirs):
    base, fresh = dirs
    # bench json absent entirely (module crashed: only an _error CSV row)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # gated metric silently dropped from an otherwise-present bench
    partial = {"fig9/llama3-8b/flowprefill_vs_distserve": 3.09}
    write_bench(fresh, "fig9", partial)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1


def test_gate_errors_without_baselines(tmp_path):
    empty = tmp_path / "empty"
    empty.mkdir()
    assert compare_main(["--baseline", str(empty),
                         "--fresh", str(empty)]) == 2


def test_gated_metric_selection():
    assert is_gated("fig18/llama3-8b/poisson/least-loaded/goodput_req_s")
    assert is_gated("fig19/llama3-8b/a800-a100/decode-aware_vs_jsq")
    assert is_gated("fig19/llama3-8b/a800-tpu/capacity-weighted/fast_share")
    assert is_gated("fig20/llama3-8b/a800-a100/s-edf+mig_vs_fcfs")
    assert is_gated("fig21/llama3-8b/b8_vs_b1_speedup")
    # fig22 prefix-cache families: goodput, ratios, hit rates, real speedup
    assert is_gated("fig22/llama3-8b/prefix-affinity/goodput_req_s")
    assert is_gated("fig22/llama3-8b/prefix-affinity_vs_no-sharing")
    assert is_gated("fig22/llama3-8b/hit_rate")
    assert is_gated("fig22/llama3-8b/real/warm_vs_cold_speedup")
    # absolute latencies are runner-speed dependent, deliberately ungated
    assert not is_gated("fig22/llama3-8b/real/cold_ms")
    assert not is_gated("fig22/llama3-8b/real/warm_ms")
    assert not is_gated("fig9/_elapsed_s")
    assert not is_gated("fig9/_error")
    # absolute tokens/s is runner-speed dependent, deliberately ungated
    assert not is_gated("fig21/llama3-8b/tokens_per_s_b8")
    assert not is_gated_lower("fig21/llama3-8b/tokens_per_s_b8")
    # the analytic-model error row is informational only
    assert not is_gated_lower("fig21/llama3-8b/analytic_prior/_real_error")
    # rel_err metrics are gated in the LOWER-is-better family, not this one
    assert not is_gated("fig19/llama3-8b/refit/refit_rel_err")
    assert is_gated_lower("fig19/llama3-8b/refit/refit_rel_err")
    assert is_gated_lower("fig21/llama3-8b/measured_prior_rel_err")
    assert not is_gated_lower("fig9/_elapsed_s")
    assert not is_gated_lower("fig18/llama3-8b/poisson/goodput_req_s")
    # fig23 tail families: the p99-goodput frontier is a RATE (higher is
    # better, matches `goodput` only), raw SLO-normalized tails are
    # lower-is-better, and the matchup ratios gate higher
    p99_frontier = "fig23/llama3-8b/heavy-tail/s-edf-decode/p99_goodput_req_s"
    assert is_gated(p99_frontier)
    assert not is_gated_lower(p99_frontier)
    assert is_gated("fig23/llama3-8b/flood/s-edf-prefill/att_goodput_req_s")
    assert is_gated("fig23/llama3-8b/heavy-tail/s-edf-decode_vs_fcfs-decode")
    tail = "fig23/llama3-8b/flood/s-edf-prefill/e2e_p99_norm"
    assert is_gated_lower(tail)
    assert not is_gated(tail)
    assert is_gated_lower("fig23/llama3-8b/ttft_p99_norm")
    # mean_tail_gap_x is informational: a tail IMPROVEMENT shrinks it, so
    # gating it either way would punish getting better
    gap = "fig23/llama3-8b/flood/s-edf-prefill/mean_tail_gap_x"
    assert not is_gated(gap)
    assert not is_gated_lower(gap)
    # fig24 colocation rows: attainments and the equal-hardware ratio gate
    # higher-is-better, for the sim pools AND the real-runtime panel
    assert is_gated("fig24/llama3-8b/flood@r4/mixed/e2e_attainment")
    assert is_gated("fig24/llama3-8b/flood@r4/mixed_vs_disagg")
    assert is_gated("fig24/llama3-8b/real/hybrid_tbt_attainment")
    assert is_gated("fig24/llama3-8b/real/hybrid_vs_dedicated")
    assert not is_gated_lower("fig24/llama3-8b/real/hybrid_vs_dedicated")
    # fig25 tiered-KV families: capacity-sweep goodputs, the tiered-vs-one-
    # tier ratio, the promote hit rate, and the real promote speedup all
    # gate higher-is-better; absolute promote latency stays ungated
    assert is_gated("fig25/llama3-8b/tiered/cap64/goodput_req_s")
    assert is_gated("fig25/llama3-8b/tiered_vs_one-tier")
    assert is_gated("fig25/llama3-8b/promote_hit_rate")
    assert is_gated("fig25/llama3-8b/real/promote_vs_recompute_speedup")
    assert not is_gated_lower("fig25/llama3-8b/promote_hit_rate")
    assert not is_gated("fig25/llama3-8b/real/promoted_ms")
    assert not is_gated("fig25/llama3-8b/real/cold_ms")
    # fig27 speculative-decoding families: both accept-regime speedups, the
    # sim attainments, and the sim TPOT ratio gate higher-is-better;
    # absolute tokens/s stays ungated (runner-speed dependent)
    assert is_gated("fig27/llama3-8b/high_accept_vs_plain_speedup")
    assert is_gated("fig27/llama3-8b/low_accept_vs_plain_speedup")
    assert is_gated("fig27/llama3-8b/sim_tbt_attainment_spec")
    assert is_gated("fig27/llama3-8b/sim_tpot_spec_vs_plain_speedup")
    assert not is_gated_lower("fig27/llama3-8b/low_accept_vs_plain_speedup")
    assert not is_gated("fig27/llama3-8b/tokens_per_s_high_accept")
    assert not is_gated("fig27/llama3-8b/tokens_per_s_plain")


def test_gate_trips_on_fig21_scaling_regression(dirs):
    """The decode-batching acceptance: the committed tolerance-compensated
    speedup threshold (3.34 * 0.9 ~= floor 3.0) must trip when the fresh
    measured scaling collapses (e.g. the batched step silently
    serializing), and pass at or above the floor."""
    base, fresh = dirs
    fig21_base = {"fig21/llama3-8b/b8_vs_b1_speedup": 3.34,
                  "fig21/llama3-8b/measured_prior_rel_err": 0.227,
                  "fig21/llama3-8b/tokens_per_s_b8": 1500.0}
    write_bench(base, "fig21", fig21_base)
    collapsed = dict(fig21_base,
                     **{"fig21/llama3-8b/b8_vs_b1_speedup": 1.1})
    write_bench(fresh, "fig21", collapsed)
    write_bench(fresh, "fig9", BASE)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # at the floor (and with a slower runner's absolute tokens/s) passes
    ok = dict(fig21_base, **{"fig21/llama3-8b/b8_vs_b1_speedup": 3.4,
                             "fig21/llama3-8b/tokens_per_s_b8": 500.0})
    write_bench(fresh, "fig21", ok)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0
    # a mis-fit measured prior (rel_err blowing past the ceiling) trips
    misfit = dict(fig21_base,
                  **{"fig21/llama3-8b/measured_prior_rel_err": 0.5})
    write_bench(fresh, "fig21", misfit)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1


def test_gate_trips_on_fig22_prefix_cache_regression(dirs):
    """The prefix-sharing acceptance: the committed >= 2x goodput ratio and
    the conservative real-runtime speedup threshold (3.34 * 0.9 ~= floor
    3.0) must trip when sharing stops paying (e.g. the trie silently always
    missing), and pass when the fresh run holds the line."""
    base, fresh = dirs
    fig22_base = {
        "fig22/llama3-8b/prefix-affinity/goodput_req_s": 24.86,
        "fig22/llama3-8b/prefix-affinity_vs_no-sharing": 2.64,
        "fig22/llama3-8b/prefix-affinity_vs_blind": 1.2,
        "fig22/llama3-8b/hit_rate": 0.594,
        "fig22/llama3-8b/real/warm_vs_cold_speedup": 3.34,
        "fig22/llama3-8b/real/cold_ms": 454.1,       # ungated wall clock
    }
    write_bench(base, "fig22", fig22_base)
    write_bench(fresh, "fig9", BASE)
    # sharing silently broken: hit rate and the goodput ratio collapse
    broken = dict(fig22_base, **{
        "fig22/llama3-8b/hit_rate": 0.02,
        "fig22/llama3-8b/prefix-affinity_vs_no-sharing": 1.01})
    write_bench(fresh, "fig22", broken)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # runtime speedup under the conservative floor trips too
    slow = dict(fig22_base,
                **{"fig22/llama3-8b/real/warm_vs_cold_speedup": 1.4})
    write_bench(fresh, "fig22", slow)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # at/above the thresholds — and with a slower runner's absolute
    # latencies — passes
    ok = dict(fig22_base, **{
        "fig22/llama3-8b/real/warm_vs_cold_speedup": 25.0,
        "fig22/llama3-8b/real/cold_ms": 2400.0})
    write_bench(fresh, "fig22", ok)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0


def test_gate_trips_on_rel_err_rise(dirs):
    """Lower-is-better gating: a rel_err metric RISING beyond tolerance must
    exit nonzero, while a drop (improvement) of any size passes."""
    base, fresh = dirs
    err_base = dict(BASE, **{"fig9/refit/refit_rel_err": 0.013})
    write_bench(base, "fig9", err_base)
    # +50% error rise (beyond +10% tolerance) trips
    worse = dict(err_base, **{"fig9/refit/refit_rel_err": 0.0195})
    write_bench(fresh, "fig9", worse)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # big improvement passes (no lower bound on an error metric)
    better = dict(err_base, **{"fig9/refit/refit_rel_err": 0.0001})
    write_bench(fresh, "fig9", better)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0
    # +5% wobble inside tolerance passes
    wobble = dict(err_base, **{"fig9/refit/refit_rel_err": 0.01365})
    write_bench(fresh, "fig9", wobble)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0
    # gated lower metric silently dropped from the fresh run trips too
    missing = {k: v for k, v in err_base.items() if "rel_err" not in k}
    write_bench(fresh, "fig9", missing)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # a 0.0 baseline (perfect error score) must not disable the gate: any
    # positive fresh value is a regression, staying at 0.0 passes
    zero_base = dict(BASE, **{"fig9/refit/refit_rel_err": 0.0})
    write_bench(base, "fig9", zero_base)
    write_bench(fresh, "fig9",
                dict(zero_base, **{"fig9/refit/refit_rel_err": 0.37}))
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    write_bench(fresh, "fig9", zero_base)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0


def test_gate_trips_on_p99_tail_regression(dirs):
    """The fig23 acceptance: the p99 family must trip in BOTH directions —
    a tail latency RISING beyond tolerance and a tail-gated frontier
    DROPPING beyond tolerance each exit nonzero — while a tail improvement
    (which also shrinks mean_tail_gap_x) passes."""
    base, fresh = dirs
    fig23_base = {
        "fig23/llama3-8b/heavy-tail/s-edf-decode/p99_goodput_req_s": 17.07,
        "fig23/llama3-8b/heavy-tail/s-edf-decode/e2e_p99_norm": 0.552,
        "fig23/llama3-8b/heavy-tail/s-edf-decode/mean_tail_gap_x": 1.41,
        "fig23/llama3-8b/heavy-tail/s-edf-decode_vs_fcfs-decode": 2.04,
    }
    write_bench(base, "fig23", fig23_base)
    write_bench(fresh, "fig9", BASE)
    # the p99 tail fattening +50% (attainment could still look fine) trips
    fat_tail = dict(fig23_base, **{
        "fig23/llama3-8b/heavy-tail/s-edf-decode/e2e_p99_norm": 0.83})
    write_bench(fresh, "fig23", fat_tail)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # the tail-gated frontier collapsing -40% trips
    collapsed = dict(fig23_base, **{
        "fig23/llama3-8b/heavy-tail/s-edf-decode/p99_goodput_req_s": 10.0})
    write_bench(fresh, "fig23", collapsed)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # so does the matchup ratio (the robust policy losing its edge)
    even = dict(fig23_base, **{
        "fig23/llama3-8b/heavy-tail/s-edf-decode_vs_fcfs-decode": 1.05})
    write_bench(fresh, "fig23", even)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # a tail IMPROVEMENT passes even though it shrinks mean_tail_gap_x —
    # that ratio is informational, not gated
    better = dict(fig23_base, **{
        "fig23/llama3-8b/heavy-tail/s-edf-decode/e2e_p99_norm": 0.3,
        "fig23/llama3-8b/heavy-tail/s-edf-decode/p99_goodput_req_s": 22.0,
        "fig23/llama3-8b/heavy-tail/s-edf-decode/mean_tail_gap_x": 1.02})
    write_bench(fresh, "fig23", better)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0
    # a +5% tail wobble inside tolerance passes
    wobble = dict(fig23_base, **{
        "fig23/llama3-8b/heavy-tail/s-edf-decode/e2e_p99_norm": 0.578})
    write_bench(fresh, "fig23", wobble)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0


def test_gate_trips_on_fig24_colocation_regression(dirs):
    """The colocation acceptance: the mixed-pool win over PD-disaggregation
    and the hybrid runtime's TBT attainment under concurrent prefill are
    both committed thresholds — losing either (dispatch mixing broken, or
    the weave starving decode) must trip; holding the line passes."""
    base, fresh = dirs
    fig24_base = {
        "fig24/llama3-8b/flood@r4/mixed/e2e_attainment": 0.884,
        "fig24/llama3-8b/flood@r4/disagg/e2e_attainment": 0.715,
        "fig24/llama3-8b/flood@r4/mixed_vs_disagg": 1.236,
        "fig24/llama3-8b/real/hybrid_tbt_attainment": 0.66,
        "fig24/llama3-8b/real/hybrid_vs_dedicated": 0.66,
    }
    write_bench(base, "fig24", fig24_base)
    write_bench(fresh, "fig9", BASE)
    # the mixed pool losing its equal-hardware edge trips
    lost = dict(fig24_base, **{
        "fig24/llama3-8b/flood@r4/mixed/e2e_attainment": 0.70,
        "fig24/llama3-8b/flood@r4/mixed_vs_disagg": 0.98})
    write_bench(fresh, "fig24", lost)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # the weave starving resident decode (TBT attainment collapsing under
    # concurrent prefill) trips
    starved = dict(fig24_base, **{
        "fig24/llama3-8b/real/hybrid_tbt_attainment": 0.3,
        "fig24/llama3-8b/real/hybrid_vs_dedicated": 0.3})
    write_bench(fresh, "fig24", starved)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # a fast runner clearing the conservative thresholds passes
    ok = dict(fig24_base, **{
        "fig24/llama3-8b/real/hybrid_tbt_attainment": 1.0,
        "fig24/llama3-8b/real/hybrid_vs_dedicated": 1.0})
    write_bench(fresh, "fig24", ok)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0


def test_gate_trips_on_fig25_tiered_kv_regression(dirs):
    """The tiered-KV acceptance: the capacity-sweep goodput floor (tiered
    holding the line where one-tier collapses) and the promote hit rate are
    committed thresholds — the tier silently dropping blocks (hits and the
    ratio collapsing) or the real promotion path degrading to recompute
    speed must trip; holding or beating the committed baseline passes."""
    base, fresh = dirs
    fig25_base = {
        "fig25/llama3-8b/tiered/cap64/goodput_req_s": 51.46,
        "fig25/llama3-8b/one-tier/cap64/goodput_req_s": 0.0,
        "fig25/llama3-8b/tiered_vs_one-tier": 3.22,
        "fig25/llama3-8b/promote_hit_rate": 1.0,
        "fig25/llama3-8b/real/promote_vs_recompute_speedup": 3.34,
        "fig25/llama3-8b/real/promoted_ms": 140.9,   # ungated wall clock
    }
    write_bench(base, "fig25", fig25_base)
    write_bench(fresh, "fig9", BASE)
    # the tier silently broken (demotion dropping content): the smallest-
    # capacity goodput collapses to the one-tier floor and promotions vanish
    broken = dict(fig25_base, **{
        "fig25/llama3-8b/tiered/cap64/goodput_req_s": 2.0,
        "fig25/llama3-8b/tiered_vs_one-tier": 0.12,
        "fig25/llama3-8b/promote_hit_rate": 0.0})
    write_bench(fresh, "fig25", broken)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # the real promotion path degrading under the conservative floor trips
    slow = dict(fig25_base, **{
        "fig25/llama3-8b/real/promote_vs_recompute_speedup": 1.2})
    write_bench(fresh, "fig25", slow)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # at/above the committed baseline — and with a slower runner's absolute
    # promote latency — passes
    ok = dict(fig25_base, **{
        "fig25/llama3-8b/real/promote_vs_recompute_speedup": 12.0,
        "fig25/llama3-8b/real/promoted_ms": 900.0})
    write_bench(fresh, "fig25", ok)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0


def test_gate_trips_on_fig27_spec_decode_regression(dirs):
    """The speculative-decoding acceptance: the committed accept-regime
    floors (1.67 * 0.9 ~= the 1.5x high-accept floor, 1.0 * 0.9 = the 0.9x
    adversarial no-regression floor) must trip when speculation stops paying
    (verify pass silently serializing) or starts costing (throttle broken,
    overhead unbounded), and pass when the fresh run holds the line."""
    base, fresh = dirs
    fig27_base = {
        "fig27/llama3-8b/high_accept_vs_plain_speedup": 1.67,
        "fig27/llama3-8b/low_accept_vs_plain_speedup": 1.0,
        "fig27/llama3-8b/sim_tbt_attainment_spec": 1.0,
        "fig27/llama3-8b/sim_tpot_spec_vs_plain_speedup": 6.2,
        "fig27/llama3-8b/tokens_per_s_plain": 1681.2,    # ungated wall clock
    }
    write_bench(base, "fig27", fig27_base)
    write_bench(fresh, "fig9", BASE)
    # speculation stops paying: the high-accept speedup collapsing under
    # the conservative floor trips
    flat = dict(fig27_base,
                **{"fig27/llama3-8b/high_accept_vs_plain_speedup": 1.1})
    write_bench(fresh, "fig27", flat)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # speculation starts costing: adversarial drafts dragging throughput
    # below the no-regression floor (EMA throttle broken) trips
    costly = dict(fig27_base,
                  **{"fig27/llama3-8b/low_accept_vs_plain_speedup": 0.6})
    write_bench(fresh, "fig27", costly)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # the deterministic sim rows are gated exactly: the spec attainment
    # dropping (scheduler mispricing multi-token steps) trips too
    mispriced = dict(fig27_base,
                     **{"fig27/llama3-8b/sim_tbt_attainment_spec": 0.8})
    write_bench(fresh, "fig27", mispriced)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 1
    # a fast runner clearing the floors — and a slower runner's absolute
    # tokens/s — passes
    ok = dict(fig27_base, **{
        "fig27/llama3-8b/high_accept_vs_plain_speedup": 3.6,
        "fig27/llama3-8b/low_accept_vs_plain_speedup": 1.02,
        "fig27/llama3-8b/tokens_per_s_plain": 400.0})
    write_bench(fresh, "fig27", ok)
    assert compare_main(["--baseline", str(base), "--fresh", str(fresh)]) == 0


def test_run_only_rejects_unknown_figure_names(capsys):
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--only", "fig9,fig99"])
    assert exc.value.code == 2
    assert "unknown figure name" in capsys.readouterr().err


def test_committed_baselines_are_wellformed():
    """The committed reference results must stay loadable and gated."""
    import os

    from benchmarks.compare import load_dir
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    baselines = load_dir(os.path.join(repo, "benchmarks", "baselines"))
    assert {"fig9", "fig18", "fig19", "fig20", "fig21", "fig22", "fig23",
            "fig24", "fig25", "fig26", "fig27"} <= set(baselines)
    gated = [m for metrics in baselines.values() for m in metrics
             if is_gated(m)]
    assert len(gated) >= 50
    # the decode-scheduling acceptance ratio is committed and actually holds
    assert baselines["fig20"]["fig20/llama3-8b/a800-a100/s-edf+mig_vs_fcfs"] \
        >= 1.15
    # the decode-batching acceptance floor is committed and actually holds
    assert baselines["fig21"]["fig21/llama3-8b/b8_vs_b1_speedup"] >= 3.0
    # the prefix-sharing acceptances are committed and actually hold:
    # >= 2x goodput over no-sharing at the ~60% hit-rate trace, affinity
    # beating blind dispatch, and the conservative >= 3x runtime speedup
    fig22 = baselines["fig22"]
    assert fig22["fig22/llama3-8b/prefix-affinity_vs_no-sharing"] >= 2.0
    assert fig22["fig22/llama3-8b/prefix-affinity_vs_blind"] > 1.0
    assert fig22["fig22/llama3-8b/hit_rate"] >= 0.55
    assert fig22["fig22/llama3-8b/real/warm_vs_cold_speedup"] >= 3.0
    # the fig23 tail acceptances are committed and actually hold: S-EDF
    # decode sustains >= 2x FCFS's tail-gated capacity under the heavy-tail
    # trace, S-EDF prefill keeps nonzero tail-gated capacity under the
    # flood (FCFS prefill has exactly zero there — the committed honest
    # collapse), and every committed mean_tail_gap_x shows the attainment-
    # gated claim overstating what the tail sustains
    fig23 = baselines["fig23"]
    assert fig23[
        "fig23/llama3-8b/heavy-tail/s-edf-decode_vs_fcfs-decode"] >= 2.0
    assert fig23[
        "fig23/llama3-8b/flood/s-edf-prefill/p99_goodput_req_s"] > 0.0
    assert fig23[
        "fig23/llama3-8b/flood/fcfs-prefill/p99_goodput_req_s"] == 0.0
    gaps = [v for m, v in fig23.items() if m.endswith("mean_tail_gap_x")]
    assert gaps and all(g >= 1.0 for g in gaps)
    # every scenario's tail statistic is gated lower-is-better
    from repro.traces.scenarios import scenario_names
    for scen in scenario_names():
        tails = [m for m in fig23
                 if f"/{scen}/" in m and is_gated_lower(m)]
        assert tails, f"no gated tail row for scenario {scen}"
    # the fig24 colocation acceptances are committed and actually hold:
    # the mixed pool beats PD-disaggregation on e2e attainment at equal
    # hardware in the flood scenario, and the hybrid runtime's decode TBT
    # attainment under concurrent prefill clears its conservative threshold
    # both absolutely and relative to a dedicated decode instance
    fig24 = baselines["fig24"]
    assert fig24["fig24/llama3-8b/flood@r4/mixed_vs_disagg"] > 1.0
    assert fig24["fig24/llama3-8b/flood@r4/mixed/e2e_attainment"] \
        > fig24["fig24/llama3-8b/flood@r4/disagg/e2e_attainment"]
    assert fig24["fig24/llama3-8b/real/hybrid_tbt_attainment"] >= 0.66
    assert fig24["fig24/llama3-8b/real/hybrid_vs_dedicated"] >= 0.66
    # the fig25 tiered-KV acceptances are committed and actually hold:
    # tiered >= 1.5x one-tier goodput at the smallest HBM capacity (where
    # one-tier's committed goodput is the honest 0.0 collapse), every hit
    # there came up a tier, and the conservative >= 3x promote-vs-recompute
    # runtime speedup
    fig25 = baselines["fig25"]
    assert fig25["fig25/llama3-8b/tiered_vs_one-tier"] >= 1.5
    assert fig25["fig25/llama3-8b/one-tier/cap64/goodput_req_s"] == 0.0
    assert fig25["fig25/llama3-8b/tiered/cap64/goodput_req_s"] > 0.0
    assert fig25["fig25/llama3-8b/promote_hit_rate"] >= 0.9
    assert fig25["fig25/llama3-8b/real/promote_vs_recompute_speedup"] >= 3.0
    # the fig27 speculative-decoding acceptances are committed and actually
    # hold: the conservative accept-regime floors (>= 1.5x high-accept after
    # tolerance, >= 0.9x adversarial no-regression after tolerance), spec
    # lifting the loaded sim decode stage's TBT attainment above plain, and
    # the deterministic sim TPOT ratio showing a real multi-token win
    fig27 = baselines["fig27"]
    assert fig27["fig27/llama3-8b/high_accept_vs_plain_speedup"] * 0.9 \
        >= 1.5
    assert fig27["fig27/llama3-8b/low_accept_vs_plain_speedup"] * 0.9 \
        >= 0.9
    assert fig27["fig27/llama3-8b/sim_tbt_attainment_spec"] \
        >= fig27["fig27/llama3-8b/sim_tbt_attainment_plain"]
    assert fig27["fig27/llama3-8b/sim_tpot_spec_vs_plain_speedup"] > 1.0
    # at least one lower-is-better (error) metric is gated too
    lower = [m for metrics in baselines.values() for m in metrics
             if is_gated_lower(m)]
    assert lower
