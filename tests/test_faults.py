"""Fault-tolerance tests: FaultPlan determinism/persistence, simulator churn
invariants (exactly-once terminal accounting under random fault
interleavings), and threaded-runtime chaos scenarios (crash+revive,
hang+watchdog, decode churn, naive-mode loss) against the real Proxy.

`run_sim_fault_case` is the scenario shared with tests/test_property.py:
fixed seeds drive it here so the invariants hold without hypothesis
installed; the property suite delegates to it with free rein over the seed
space.
"""
import dataclasses
import math
import threading
import time

import numpy as np
import pytest

from repro.core import Request, RequestState, SchedulerCore, TTFTPredictor
from repro.core.faults import FaultEvent, FaultPlan, merge_plans
from repro.sim.cluster import simulate_cluster

# --- FaultPlan: determinism, validation, persistence -------------------------


def test_generate_is_deterministic():
    a = FaultPlan.generate(7, n_instances=4, duration=60.0, rate=0.1)
    b = FaultPlan.generate(7, n_instances=4, duration=60.0, rate=0.1)
    assert a.events == b.events and a.seed == 7
    c = FaultPlan.generate(8, n_instances=4, duration=60.0, rate=0.1)
    assert a.events != c.events
    # schedule is time-sorted and in-range
    times = [e.time for e in a]
    assert times == sorted(times)
    assert all(0 <= e.time < 60.0 and 0 <= e.instance < 4 for e in a)


def test_plan_json_roundtrip_including_inf_duration():
    plan = FaultPlan(events=(
        FaultEvent(time=1.0, instance=0, kind="crash", duration=math.inf),
        FaultEvent(time=2.0, instance=1, kind="spot", notice=1.5,
                   duration=4.0),
        FaultEvent(time=3.0, instance=2, kind="slowdown", factor=3.0,
                   duration=2.0, target="decode"),
    ), seed=42)
    back = FaultPlan.from_json(plan.to_json())
    assert back == plan
    assert math.isinf(back.events[0].duration)


def test_from_spec_preset_seed_and_file(tmp_path):
    assert len(FaultPlan.from_spec("churn")) == 1
    assert FaultPlan.from_spec("seed:5").seed == 5
    p = tmp_path / "plan.json"
    p.write_text(FaultPlan.preset("gray").to_json())
    assert FaultPlan.from_spec(str(p)) == FaultPlan.preset("gray")
    with pytest.raises(ValueError, match="neither a preset"):
        FaultPlan.from_spec("no-such-preset")


def test_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(time=0.0, instance=0, kind="meteor")
    with pytest.raises(ValueError, match="unknown fault target"):
        FaultEvent(time=0.0, instance=0, target="gateway")
    with pytest.raises(ValueError, match="duration"):
        FaultEvent(time=0.0, instance=0, duration=0.0)
    with pytest.raises(ValueError, match="factor"):
        FaultEvent(time=0.0, instance=0, kind="slowdown", factor=1.0)
    # spot timing: serves through the notice, rejoins after the outage
    e = FaultEvent(time=10.0, instance=0, kind="spot", notice=2.0,
                   duration=5.0)
    assert e.down_at == 12.0 and e.up_at == 17.0


def test_merge_plans_time_sorted():
    m = merge_plans([FaultPlan.preset("churn", duration=30.0),
                     FaultPlan.preset("gray", duration=30.0)])
    times = [e.time for e in m]
    assert times == sorted(times) and len(m) == 3


# --- simulator churn: exactly-once terminal accounting -----------------------


def run_sim_fault_case(rng):
    """One random churn scenario through ClusterSim; asserts the invariants
    that must hold under ANY fault interleaving:

      * every request reaches EXACTLY one terminal state — served (has a
        first token) or DROPPED — never both, never neither;
      * counters conserve: served + lost + shed == submitted, and the
        result's shed/lost tallies match the per-request states;
      * with retry recovery, a loss only happens past the retry budget;
      * the run terminates with a finite makespan (no wedged instances).
    """
    n = int(rng.integers(20, 60))
    reqs = [Request(num_tokens=int(rng.integers(200, 8000)),
                    slo=float(rng.uniform(0.5, 6.0)),
                    arrival=round(float(rng.uniform(0.0, 20.0)), 3),
                    output_tokens=int(rng.integers(0, 24)),
                    tbt_slo=1.0)
            for _ in range(n)]
    decode = int(rng.integers(0, 3))
    plan = merge_plans([
        FaultPlan.generate(int(rng.integers(0, 2**31)), n_instances=3,
                           duration=25.0, rate=0.15, mean_outage=4.0),
        FaultPlan.generate(int(rng.integers(0, 2**31)),
                           n_instances=max(decode, 1), duration=25.0,
                           rate=0.1, mean_outage=3.0, target="decode"),
    ]) if decode else FaultPlan.generate(
        int(rng.integers(0, 2**31)), n_instances=3, duration=25.0,
        rate=0.15, mean_outage=4.0)
    max_retries = int(rng.integers(1, 5))
    shed_policy = ("off", "doomed-only", "budget")[int(rng.integers(0, 3))]
    res = simulate_cluster(
        "flowprefill", reqs, num_instances=3, decode_instances=decode,
        dispatch="least-loaded", fault_plan=plan, recovery="retry",
        max_retries=max_retries, retry_backoff=0.05, watchdog_s=1.0,
        shed_policy=shed_policy, shed_budget=1.5)

    assert len(res.requests) == n
    served = [r for r in res.requests if r.state is not RequestState.DROPPED]
    dropped = [r for r in res.requests if r.state is RequestState.DROPPED]
    for r in served:
        # terminal means actually served: a first token exists and, when the
        # request decodes, it finished
        assert r.first_token_time is not None
        if r.output_tokens and decode:
            assert r.finish_time is not None
    shed = [r for r in dropped if r.shed]
    lost = [r for r in dropped if not r.shed]
    for r in lost:   # loss only past the retry budget under retry recovery
        assert r.retries > max_retries
    for r in shed:   # shedding happens at admission, before any attempt
        assert r.retries == 0 and r.first_token_time is None
    assert res.shed_requests == len(shed)
    assert res.lost_requests == len(lost)
    assert len(served) + len(lost) + len(shed) == n
    assert math.isfinite(res.makespan)
    assert res.retries >= 0
    return res


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 17, 23, 1234, 99991])
def test_sim_fault_interleavings_exactly_once(seed):
    run_sim_fault_case(np.random.default_rng(seed))


def test_sim_faults_off_counters_zero():
    """The churn code paths are inert without a plan: zero fault counters
    and full service (the byte-equality of the fig baselines is gated in
    benchmarks; this is the cheap in-tree canary)."""
    rng = np.random.default_rng(5)
    reqs = [Request(num_tokens=int(rng.integers(500, 4000)), slo=5.0,
                    arrival=float(i) * 0.2) for i in range(20)]
    res = simulate_cluster("flowprefill", reqs, num_instances=2,
                           recovery="retry", watchdog_s=1.0,
                           shed_policy="off")
    assert res.retries == res.shed_requests == res.lost_requests == 0
    assert all(r.first_token_time is not None for r in res.requests)


def test_sim_naive_recovery_loses_stranded():
    """recovery="none" on a mid-trace crash with no rejoin loses exactly
    the stranded work, and the fault-tolerant run on the SAME plan and
    trace loses nothing."""
    reqs = [Request(num_tokens=2000, slo=10.0, arrival=float(i) * 0.5)
            for i in range(24)]
    plan = FaultPlan(events=(
        FaultEvent(time=3.0, instance=0, kind="crash", duration=math.inf),))
    naive = simulate_cluster("flowprefill", reqs, num_instances=2,
                             fault_plan=plan, recovery="none")
    ft = simulate_cluster("flowprefill", reqs, num_instances=2,
                          fault_plan=plan, recovery="retry")
    assert naive.lost_requests > 0
    assert ft.lost_requests == 0 and ft.retries >= naive.lost_requests
    assert ft.attainment >= naive.attainment


def test_sim_shedding_rejects_only_doomed():
    """Shedding engages only under overload, never with a loose budget, and
    rejecting the doomed tail does not hurt the requests that were
    admitted."""
    rng = np.random.default_rng(11)
    reqs = [Request(num_tokens=int(rng.integers(4000, 16000)), slo=0.8,
                    arrival=float(i) * 0.05) for i in range(40)]
    off = simulate_cluster("flowprefill", reqs, num_instances=2,
                           shed_policy="off")
    doomed = simulate_cluster("flowprefill", reqs, num_instances=2,
                              shed_policy="doomed-only")
    budget = simulate_cluster("flowprefill", reqs, num_instances=2,
                              shed_policy="budget", shed_budget=1.2)
    loose = simulate_cluster("flowprefill", reqs, num_instances=2,
                             shed_policy="budget", shed_budget=1e9)
    assert off.shed_requests == 0
    assert loose.shed_requests == 0     # a generous budget admits everything
    assert doomed.shed_requests > 0 and budget.shed_requests > 0
    # shedding the doomed tail must not hurt the admitted requests
    adm = [r for r in doomed.requests if not r.shed]
    adm_off = [r for r in off.requests if r.rid in {a.rid for a in adm}]
    att = sum(r.slo_met for r in adm) / max(len(adm), 1)
    att_off = sum(r.slo_met for r in adm_off) / max(len(adm_off), 1)
    assert att >= att_off


# --- threaded runtime chaos ---------------------------------------------------

jax = pytest.importorskip("jax")

import jax.numpy as jnp                                 # noqa: E402

from repro.configs.base import get_tiny_config          # noqa: E402
from repro.models import init_params                    # noqa: E402
from repro.models.segments import SegmentedPrefill      # noqa: E402
from repro.serving.decode_instance import DecodeInstance  # noqa: E402
from repro.serving.prefill_instance import PrefillInstance  # noqa: E402
from repro.serving.proxy import Proxy                   # noqa: E402

CFG = dataclasses.replace(get_tiny_config("llama3_8b"),
                          num_layers=2, d_model=64, d_ff=128)
MAX_SEQ = 512


@pytest.fixture(scope="module")
def chaos_model():
    params = init_params(CFG, jax.random.PRNGKey(0))
    ex = SegmentedPrefill(params, CFG, max_seq=MAX_SEQ, granularity="op",
                          chunk_tokens=128)
    pred = TTFTPredictor(coeffs=np.array([1e-4, 0.0]), floor=0.0)
    return params, ex, pred


def _mk_prefill(params, ex, pred):
    core = SchedulerCore(predictor=pred, policy="s-edf",
                         enable_batching=False)
    return PrefillInstance(params, CFG, core, max_seq=MAX_SEQ,
                           attn_impl="xla", executor=ex)


def _assert_chaos_invariants(name, proxy, decs, reqs):
    """The runtime mirror of `run_sim_fault_case`'s invariants: exactly-once
    completion, conservation of finished+lost, and KV block accounting
    (only the decode scratch slot may remain resident after drain)."""
    rep = proxy.report()
    served = [r for r in reqs if r.state is not RequestState.DROPPED]
    fin_rids = [r.rid for d in decs for r in d.finished]
    assert len(fin_rids) == len(set(fin_rids)), \
        f"{name}: a request completed twice"
    for r in served:
        assert r.first_token_time is not None, \
            f"{name}: rid {r.rid} neither served nor declared lost"
    if decs:   # every served request made it through decode exactly once
        assert set(fin_rids) == {r.rid for r in served}
        assert all(r.finish_time is not None for r in served)
    assert len(served) + rep["lost_requests"] == len(reqs), \
        f"{name}: {len(served)} served + {rep['lost_requests']} lost " \
        f"!= {len(reqs)} submitted"
    assert rep["stranded_rids"] == [], \
        f"{name}: non-terminal requests left after drain"
    for d in decs:
        if d.kv is not None:
            live = d.kv.num_blocks - d.kv.free_blocks
            assert live <= 1, f"{name}: {live} KV blocks leaked after drain"
    return rep


def _run_chaos(params, ex, pred, *, n_prefill=2, n_decode=1, n_reqs=10,
               fault_at=4, fault=None, seed=0, drain_s=120.0, **proxy_kw):
    insts = [_mk_prefill(params, ex, pred) for _ in range(n_prefill)]
    decs = [DecodeInstance(params, CFG, decode_tokens=4, policy="fcfs")
            for _ in range(n_decode)]
    proxy = Proxy(insts, decs, dispatch="round-robin",
                  retry_backoff=0.02, retry_backoff_cap=0.2, **proxy_kw)
    rng = np.random.default_rng(seed)
    reqs = []
    try:
        for i in range(n_reqs):
            n = int(rng.integers(64, 256))
            r = Request(num_tokens=n, slo=30.0, arrival=time.monotonic(),
                        output_tokens=4 if n_decode else 0,
                        tbt_slo=5.0 if n_decode else None)
            reqs.append(r)
            proxy.submit(r, rng.integers(0, CFG.vocab_size, size=n))
            time.sleep(0.01)
            if i == fault_at and fault is not None:
                fault(proxy, insts, decs)
        assert proxy.drain(drain_s), "drain timed out mid-recovery"
        return proxy, insts, decs, reqs
    except BaseException:
        proxy.shutdown()
        raise


def test_runtime_no_fault_baseline(chaos_model):
    proxy, _, decs, reqs = _run_chaos(*chaos_model, fault=None)
    try:
        rep = _assert_chaos_invariants("no-fault", proxy, decs, reqs)
        assert rep["retries"] == rep["lost_requests"] == 0
        assert all(rep["instance_health"]["prefill"])
    finally:
        proxy.shutdown()


def test_runtime_crash_and_revive_recovers_all(chaos_model):
    def fault(proxy, insts, decs):
        proxy.kill_instance(0, "prefill")
        threading.Timer(0.3, proxy.revive_instance,
                        args=(0, "prefill")).start()

    proxy, _, decs, reqs = _run_chaos(*chaos_model, fault=fault)
    try:
        rep = _assert_chaos_invariants("crash+revive", proxy, decs, reqs)
        assert rep["lost_requests"] == 0        # stranded work re-dispatched
        assert rep["retries"] >= 1              # ... by charging retries
        assert all(rep["instance_health"]["prefill"])  # revive took
    finally:
        proxy.shutdown()


def test_runtime_decode_crash_recovers_all(chaos_model):
    def fault(proxy, insts, decs):
        # crash the decode instance only once it actually holds in-flight
        # work: under heavy external load no prefill may have completed by
        # the time the submit loop reaches the kill point, and crashing an
        # EMPTY decode instance strands nothing (retries would stay 0)
        deadline = time.monotonic() + 30.0
        while decs[0].idle() and time.monotonic() < deadline:
            time.sleep(0.005)
        proxy.kill_instance(0, "decode")
        threading.Timer(0.3, proxy.revive_instance,
                        args=(0, "decode")).start()

    proxy, _, decs, reqs = _run_chaos(*chaos_model, n_prefill=1, n_decode=2,
                                      fault=fault)
    try:
        rep = _assert_chaos_invariants("decode-crash", proxy, decs, reqs)
        # a decode-stranded request needs a FULL re-prefill (its KV died
        # with the instance), so recovery shows up as prefill retries
        assert rep["lost_requests"] == 0
        assert rep["retries"] >= 1
    finally:
        proxy.shutdown()


def test_runtime_hang_detected_by_watchdog(chaos_model):
    """A hung (not dead) worker makes no progress; the watchdog must strand
    its work, the supervisor auto-restarts it, and every request still
    finishes exactly once."""
    params, ex, pred = chaos_model

    # Calibrate the watchdog period to THIS machine under its CURRENT load
    # (the test_fig8 pattern): a fixed period cannot separate the injected
    # hang from an honest CPU-starvation stall when the whole suite (or a
    # loaded CI runner) competes for cores — a spuriously-stranded slow
    # instance then burns retry budget on work that was progressing. One
    # warm full prefill pass of the largest request is the yardstick for
    # "an honest stall"; the period must dwarf it, and the injected hang
    # must dwarf the period so detection stays unambiguous.
    toks = jnp.zeros((1, 256), jnp.int32)
    ex.run_all(ex.start(toks))                      # warm (jit + pools)
    t0 = time.monotonic()
    ex.run_all(ex.start(toks))
    wd = min(4.0, max(0.4, 25 * (time.monotonic() - t0)))
    hang = max(1.0, 2.5 * wd)

    def fault(proxy, insts, decs):
        insts[0].inject_fault(("hang", hang))
        decs[0].inject_fault(("hang", hang))

    # max_retries must cover the whole hang: the zombie sleep outlasts one
    # watchdog+auto-restart cycle, so the watchdog legitimately re-strands
    # the same work several times before the worker wakes — and the sole
    # decode instance gives those requests nowhere else to go. Each fire
    # charges a retry; the default budget of 3 sits exactly at that cliff.
    # The invariant under test is detect-and-recover exactly-once, not
    # budget exhaustion (naive-mode covers loss), so the budget is sized
    # far past any plausible fire count.
    # drain budget scales with the hang: under heavy external load the
    # recovery storm legitimately takes several watchdog+restart cycles to
    # quiesce (a cap, not a sleep — the uncontended run still settles fast)
    proxy, _, decs, reqs = _run_chaos(*chaos_model, n_reqs=8, fault=fault,
                                      watchdog_s=wd,
                                      auto_restart_s=1.25 * wd,
                                      max_retries=50,
                                      drain_s=max(120.0, 30 * wd))
    try:
        rep = _assert_chaos_invariants("hang+watchdog", proxy, decs, reqs)
        assert rep["lost_requests"] == 0
        assert rep["retries"] >= 1              # watchdog fired at least once
    finally:
        proxy.shutdown()


def test_runtime_naive_mode_loses_stranded(chaos_model):
    """recovery="none" is the contrast case: a crash with no revive loses
    exactly the stranded requests, and the report names them."""
    params, ex, pred = chaos_model
    insts = [_mk_prefill(params, ex, pred) for _ in range(2)]
    proxy = Proxy(insts, [], dispatch="round-robin", recovery="none")
    rng = np.random.default_rng(0)
    reqs = []
    try:
        # pin instance 0 so its queue cannot drain before the kill (a warm
        # jit cache otherwise empties it between submit and crash)
        insts[0].inject_fault(("hang", 0.5))
        for i in range(8):
            n = int(rng.integers(64, 256))
            r = Request(num_tokens=n, slo=30.0, arrival=time.monotonic())
            reqs.append(r)
            proxy.submit(r, rng.integers(0, CFG.vocab_size, size=n))
        proxy.kill_instance(0, "prefill")   # strands its queued requests
        assert proxy.drain(60.0)
        rep = _assert_chaos_invariants("naive", proxy, [], reqs)
        assert rep["lost_requests"] > 0
        assert rep["lost_rids"] == sorted(
            r.rid for r in reqs if r.state is RequestState.DROPPED)
        # the healthy instance still served its share
        assert rep["lost_requests"] < len(reqs)
    finally:
        proxy.shutdown()


def test_runtime_shed_policy_rejects_doomed(chaos_model):
    """Proxy admission control mirrors the sim: with every instance busy
    and a predicted TTFT already past the SLO, a fresh arrival is shed
    (DROPPED + shed, never dispatched) instead of deepening the queue."""
    params, ex, pred = chaos_model
    insts = [_mk_prefill(params, ex, pred)]
    # a predictor that makes every request look doomed once one is queued
    slow_pred = TTFTPredictor(coeffs=np.array([1.0, 0.0]), floor=0.0)
    proxy = Proxy(insts, [], dispatch="round-robin",
                  shed_policy="doomed-only", predictor=slow_pred)
    try:
        rng = np.random.default_rng(3)
        reqs = []
        for i in range(4):
            r = Request(num_tokens=128, slo=0.5, arrival=time.monotonic())
            reqs.append(r)
            proxy.submit(r, rng.integers(0, CFG.vocab_size, size=128))
        assert proxy.drain(60.0)
        rep = proxy.report()
        shed = [r for r in reqs if r.shed]
        assert rep["shed_requests"] == len(shed) >= 1
        assert all(r.state is RequestState.DROPPED and
                   r.first_token_time is None for r in shed)
        # the first arrival found an empty instance: never shed
        assert not reqs[0].shed
        assert rep["lost_requests"] == 0
    finally:
        proxy.shutdown()
