"""Hybrid colocation runtime: `HybridSchedulerCore` invariants (property
style — hypothesis-backed when installed, seeded fallback otherwise) and
`HybridInstance` end-to-end parity against the standalone engines.

The four ISSUE-level properties:

  1. the token budget is never exceeded (decode tokens + prefill slice
     tokens == budget_used <= token_budget);
  2. a resident decode row is never skipped two consecutive steps whenever
     the candidate set fits twice the budget (the owed-rows carry);
  3. a preempted prefill resumes at exactly its operator offset — slices
     always start where the previous admitted slice ended, no recompute,
     no gap, monotone to completion;
  4. with ``policy="fcfs"`` and the budget/caps unbounded the hybrid plan
     is bit-identical to what the standalone `DecodeSchedulerCore` /
     `SchedulerCore` would run.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_tiny_config
from repro.core import Request
from repro.core.predictor import OnlineTTFTPredictor
from repro.core.scheduler import (DecodeEntry, DecodeSchedulerCore,
                                  HybridSchedulerCore, SchedulerCore)
from repro.models import init_params
from repro.models.model import decode_step, prefill
from repro.serving.decode_instance import DecodeInstance
from repro.serving.hybrid_instance import HybridInstance

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


# ------------------------------------------------- scheduler-core fixtures

def make_core(policy="s-edf", decode_policy="s-edf", budget=64, chunk=16,
              cap=0):
    pred = OnlineTTFTPredictor(coeffs=np.array([0.0, 1e-4, 0.0]))
    return HybridSchedulerCore(
        prefill=SchedulerCore(predictor=pred, policy=policy,
                              enable_batching=False),
        decode=DecodeSchedulerCore(policy=decode_policy),
        token_budget=budget, chunk_tokens=chunk, decode_max_batch=cap)


def make_prefills(specs):
    """specs: [(num_tokens, slo, arrival)] -> Requests (deterministic rids
    within one call via fresh construction order)."""
    return [Request(num_tokens=n, slo=s, arrival=a) for n, s, a in specs]


def make_entries(specs):
    """specs: [(remaining, deadline, order)] -> DecodeEntries keyed 0..n-1."""
    return [DecodeEntry(key=i, remaining_tokens=float(r), deadline=d,
                        order=o)
            for i, (r, d, o) in enumerate(specs)]


def check_plan_shape(core, plan, prefills, done, entries):
    """Property 1 (+ structural sanity): the budget bound and exact token
    accounting, one slice per request, offsets at the resume point."""
    slice_tokens = sum(s.n_tokens for s in plan.prefill_slices)
    assert plan.budget_used == len(plan.decode_keys) + slice_tokens
    if core.token_budget > 0:
        assert plan.budget_used <= core.token_budget
    assert len(plan.decode_keys) == len(set(plan.decode_keys))
    assert not (set(plan.decode_keys) & set(plan.preempted_decode))
    assert set(plan.decode_keys) <= {e.key for e in entries}
    by_rid = {r.rid: r for r in prefills}
    seen = set()
    for s in plan.prefill_slices:
        assert s.key not in seen, "a request sliced twice in one step"
        seen.add(s.key)
        assert s.n_tokens >= 1
        assert s.offset == int(done.get(s.key, 0)), \
            "slice must start at the request's resume offset"
        assert s.offset + s.n_tokens <= by_rid[s.key].num_tokens


def drive(core, prefills, entries, n_steps=40, now0=0.0, dt=0.01,
          t_step=0.001):
    """Run the scheduler loop the way the runtime does: advance ``done`` by
    each admitted slice, decrement admitted decodes, keep ``resident`` as
    the previous step's batch. Returns per-step (plan, skipped_residents).
    Checks properties 1 and 3 at every step."""
    done = {r.rid: 0 for r in prefills}
    remaining = {e.key: e.remaining_tokens for e in entries}
    orders = {e.key: e.order for e in entries}
    deadlines = {e.key: e.deadline for e in entries}
    resident = set()
    history = []
    live_prefills = list(prefills)
    for i in range(n_steps):
        now = now0 + i * dt
        live_entries = [DecodeEntry(key=k, remaining_tokens=remaining[k],
                                    deadline=deadlines[k], order=orders[k])
                        for k in sorted(remaining) if remaining[k] > 0]
        if not live_prefills and not live_entries:
            break
        plan = core.plan_step(now, prefill=live_prefills, prefill_done=done,
                              decode_entries=live_entries,
                              decode_resident=resident, t_step=t_step)
        check_plan_shape(core, plan, live_prefills, done, live_entries)
        skipped = {e.key for e in live_entries
                   if e.key in resident} - set(plan.decode_keys)
        history.append((plan, skipped))
        for s in plan.prefill_slices:
            done[s.key] += s.n_tokens                 # property 3: the next
        for k in plan.decode_keys:                    # slice resumes HERE
            remaining[k] -= 1
        live_prefills = [r for r in live_prefills
                         if done[r.rid] < r.num_tokens]
        resident = set(plan.decode_keys)
    return history, done, remaining


def check_no_double_skip(core, history, n_entries):
    """Property 2: whenever the BUDGET is the binding constraint and the
    candidate set fits twice the budget, a resident row the budget squeezed
    out is admitted the very next step (the owed-rows carry). A binding
    slot CAP instead keeps the standalone S-EDF semantics — priority-based
    preemption with no fairness carry — so the guarantee is scoped to the
    budget-binding regime, exactly as `_select_decode` documents."""
    budget, cap = core.token_budget, core.decode_max_batch
    if budget <= 0 or (cap > 0 and budget >= cap):
        return                  # the budget is never the binding constraint
    if n_entries > 2 * budget:
        return                  # outside the guarantee precondition
    for (_, skipped_a), (plan_b, _) in zip(history, history[1:]):
        missed_twice = skipped_a - set(plan_b.decode_keys)
        assert not missed_twice, \
            f"resident rows {missed_twice} skipped twice consecutively"


def run_property_case(rng):
    """One randomized scenario; shared by the hypothesis wrapper and the
    seeded fallback."""
    n_pre = int(rng.integers(0, 6))
    n_dec = int(rng.integers(0, 10))
    budget = int(rng.integers(1, 40))
    chunk = int(rng.integers(1, 24))
    cap = int(rng.integers(0, 6))
    core = make_core(budget=budget, chunk=chunk, cap=cap)
    prefills = make_prefills(
        [(int(rng.integers(1, 200)), float(rng.uniform(0.5, 30.0)),
          float(rng.uniform(0.0, 1.0))) for _ in range(n_pre)])
    entries = make_entries(
        [(int(rng.integers(1, 12)),
          float(rng.uniform(0.5, 60.0)) if rng.random() < 0.8
          else float("inf"), i) for i in range(n_dec)])
    # every step with live work admits >= 1 token (budget >= 1), so this
    # bound suffices for the liveness check below
    total = (sum(r.num_tokens for r in prefills)
             + sum(int(e.remaining_tokens) for e in entries))
    history, done, remaining = drive(core, prefills, entries,
                                     n_steps=total + 5)
    check_no_double_skip(core, history, n_dec)
    # liveness: with a positive budget everything eventually drains
    assert all(done[r.rid] == r.num_tokens for r in prefills)
    assert all(v <= 0 for v in remaining.values())


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=80, deadline=None)
    def test_hybrid_core_properties(seed):
        run_property_case(np.random.default_rng(seed))
else:                                                 # pragma: no cover
    @pytest.mark.parametrize("seed", [0, 1, 2, 7, 13, 42, 99, 123, 2024,
                                      31337])
    def test_hybrid_core_properties(seed):
        run_property_case(np.random.default_rng(seed))


def test_budget_binding_owed_carry():
    """Deterministic instance of property 2: 3 resident rows, budget 2 —
    the squeezed-out row must be admitted (ahead of rank) next step."""
    core = make_core(budget=2, chunk=8, cap=0)
    entries = make_entries([(5, 1.0, 0), (5, 2.0, 1), (5, 3.0, 2)])
    resident = {e.key for e in entries}
    plan1 = core.plan_step(0.0, prefill=[], prefill_done={},
                           decode_entries=entries, decode_resident=resident,
                           t_step=0.001)
    assert len(plan1.decode_keys) == 2
    (skipped,) = resident - set(plan1.decode_keys)
    assert plan1.preempted_decode == [skipped]
    plan2 = core.plan_step(0.01, prefill=[], prefill_done={},
                           decode_entries=entries,
                           decode_resident=set(plan1.decode_keys),
                           t_step=0.001)
    assert skipped in plan2.decode_keys, \
        "budget-preempted resident not admitted the next step"


def test_preempted_prefill_resumes_at_offset():
    """Deterministic instance of property 3: a long relaxed prefill is
    starved by a strict one, then resumes at exactly the token it left."""
    core = make_core(budget=8, chunk=8, cap=0)
    long_r, short_r = make_prefills([(64, 60.0, 0.0), (16, 0.2, 0.05)])
    done = {long_r.rid: 0, short_r.rid: 0}
    plan = core.plan_step(0.0, prefill=[long_r], prefill_done=done,
                          decode_entries=[], decode_resident=set())
    assert plan.prefill_slices[0].key == long_r.rid
    done[long_r.rid] = 8
    # the strict request arrives and takes the whole budget (S-EDF)
    plan = core.plan_step(0.06, prefill=[long_r, short_r], prefill_done=done,
                          decode_entries=[], decode_resident=set())
    assert plan.prefill_slices[0].key == short_r.rid
    assert plan.prefill_slices[0].offset == 0
    # after the strict one drains, the long request resumes AT TOKEN 8
    done[short_r.rid] = 16
    plan = core.plan_step(0.12, prefill=[long_r], prefill_done=done,
                          decode_entries=[], decode_resident=set())
    s = plan.prefill_slices[0]
    assert (s.key, s.offset, s.n_tokens) == (long_r.rid, 8, 8)


def fcfs_identity_case(rng):
    """Property 4: fcfs + unbounded budget/caps == the standalone engines."""
    core = make_core(policy="fcfs", decode_policy="fcfs", budget=0, chunk=0,
                     cap=0)
    prefills = make_prefills(
        [(int(rng.integers(1, 100)), 10.0, float(rng.uniform(0, 2)))
         for _ in range(int(rng.integers(0, 6)))])
    done = {r.rid: int(rng.integers(0, r.num_tokens)) for r in prefills}
    entries = make_entries(
        [(int(rng.integers(1, 8)), float(rng.uniform(0.5, 10.0)), i)
         for i in range(int(rng.integers(0, 6)))])
    resident = {e.key for e in entries if rng.random() < 0.5}
    now = 1.0
    plan = core.plan_step(now, prefill=prefills, prefill_done=done,
                          decode_entries=entries, decode_resident=resident,
                          t_step=0.001)
    want_batch, want_pre = DecodeSchedulerCore(policy="fcfs").select_batch(
        entries, resident, 0, now, 0.001)
    assert plan.decode_keys == want_batch
    assert plan.preempted_decode == want_pre == []
    ranked = SchedulerCore(
        predictor=OnlineTTFTPredictor(coeffs=np.array([0.0, 1e-4, 0.0])),
        policy="fcfs", enable_batching=False).rank(prefills, now)
    want_slices = [(r.rid, done[r.rid], r.num_tokens - done[r.rid])
                   for r in ranked if r.num_tokens > done[r.rid]]
    assert [(s.key, s.offset, s.n_tokens)
            for s in plan.prefill_slices] == want_slices


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=60, deadline=None)
    def test_fcfs_unbounded_matches_standalone(seed):
        fcfs_identity_case(np.random.default_rng(seed))
else:                                                 # pragma: no cover
    @pytest.mark.parametrize("seed", [0, 3, 5, 11, 17, 23, 101, 999])
    def test_fcfs_unbounded_matches_standalone(seed):
        fcfs_identity_case(np.random.default_rng(seed))


# ------------------------------------------------ runtime (HybridInstance)

CFG = dataclasses.replace(get_tiny_config("llama3_8b"),
                          num_layers=2, d_model=128, d_ff=256)
MAX_SEQ = 128
PROMPT = 64                         # ONE prompt length: one compile footprint
OUT = 5


@pytest.fixture(scope="module")
def model():
    return init_params(CFG, jax.random.PRNGKey(0))


def _tokens(seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, PROMPT).astype(np.int32)


def _reference(params, toks, n_out):
    """Standalone engines' answer: dense prefill + greedy decode_step loop —
    the trajectory the hybrid's pool-backed ragged path must bit-match."""
    logits, cache = prefill(params, CFG, {"tokens": jnp.asarray(toks[None])},
                            max_seq=MAX_SEQ)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [int(tok[0])]
    c = {"k": cache["k"], "v": cache["v"], "pos": cache["pos"]}
    for _ in range(n_out):
        logits, c = decode_step(params, CFG, tok, c)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


def make_hybrid(params, **kw):
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("token_budget", 256)
    kw.setdefault("chunk_tokens", 32)
    kw.setdefault("decode_max_batch", 4)
    kw.setdefault("decode_cadence", 0.002)
    kw.setdefault("kv_block_size", 16)
    kw.setdefault("kv_pool_blocks", 64)
    kw.setdefault("prefix_share", False)
    return HybridInstance(params, CFG, **kw)


def _req(out_tokens=OUT, slo=30.0, tbt=10.0):
    return Request(num_tokens=PROMPT, slo=slo, arrival=time.monotonic(),
                   output_tokens=out_tokens, tbt_slo=tbt)


def test_local_decode_parity(model):
    """3 concurrent requests prefill AND decode on one hybrid worker; every
    emitted trajectory (first token + all decoded tokens) bit-matches the
    standalone dense reference — the no-handoff phase transition loses
    nothing."""
    inst = make_hybrid(model)
    reqs, toks = [], {}
    try:
        for seed in (0, 1, 2):
            t = _tokens(seed)
            r = _req()
            toks[r.rid] = t
            reqs.append(r)
            inst.submit(r, t)
        assert inst.drain(120.0), "hybrid instance did not drain"
    finally:
        inst.shutdown()
    assert inst.rounds > 0 and inst.steps > 0
    got = {j.request.rid: j.emitted for j in inst.finished_jobs}
    assert set(got) == {r.rid for r in reqs}
    for r in reqs:
        want = _reference(model, toks[r.rid], OUT)
        assert got[r.rid] == want, f"rid {r.rid}: {got[r.rid]} != {want}"
        assert r.finish_time is not None and r.first_token_time is not None
        assert len(got[r.rid]) == OUT + 1


def test_prefix_share_warm_parity(model):
    """Resubmitting a prompt hits the trie-cached blocks (suffix-only
    compute) and still emits the identical trajectory."""
    inst = make_hybrid(model, prefix_share=True)
    t = _tokens(7)
    try:
        a = _req()
        inst.submit(a, t)
        assert inst.drain(120.0)
        assert inst.prefix_hits == 0
        b = _req()
        inst.submit(b, t)
        assert inst.drain(120.0)
    finally:
        inst.shutdown()
    assert inst.prefix_hits == 1
    # block size 16, 64-token prompt: all 4 blocks cached, hit capped n-1
    assert inst.prefix_hit_tokens == PROMPT - 1
    got = {j.request.rid: j.emitted for j in inst.finished_jobs}
    want = _reference(model, t, OUT)
    assert got[a.rid] == want
    assert got[b.rid] == want, "warm (prefix-hit) trajectory diverged"


def test_prefill_only_request_frees_pool(model):
    """output_tokens=0 is a legitimate prefill-only request (fig24's
    concurrent-prefill pressure): it completes without joining decode and
    returns its blocks to the pool."""
    inst = make_hybrid(model)
    free0 = inst.kv.accounting()[0]
    try:
        r = Request(num_tokens=PROMPT, slo=30.0, arrival=time.monotonic(),
                    output_tokens=0)
        inst.submit(r, _tokens(9))
        assert inst.drain(60.0)
        assert r in inst.prefilled and not inst.finished
        assert r.first_token_time is not None
        free, live, cached, total = inst.kv.accounting()
        assert free + live + cached == total
        assert free == free0, "prefill-only request leaked pool blocks"
    finally:
        inst.shutdown()


def test_offload_handoff_matches_reference(model):
    """Mixed-pool mode: the dense cache `_offload` extracts feeds a real
    DecodeInstance to the same final token as the standalone reference."""
    handed = []
    inst = make_hybrid(model, on_decode_ready=handed.append)
    t = _tokens(11)
    want = _reference(model, t, OUT)
    try:
        r = _req()
        inst.submit(r, t)
        assert inst.drain(60.0)          # offload mode: drains at prefill end
    finally:
        inst.shutdown()
    assert len(handed) == 1 and handed[0].first_token == want[0]
    assert int(handed[0].cache["pos"]) == PROMPT
    dec = DecodeInstance(model, CFG, decode_tokens=OUT, decode_max_batch=1)
    try:
        dec.submit(handed[0])
        assert dec.drain(60.0)
    finally:
        dec.shutdown()
    assert handed[0].next_token == want[-1], \
        "offloaded cache decodes differently from the dense reference"


def test_tight_budget_still_completes(model):
    """A budget smaller than one chunk (prefill slices truncated every
    round) still drains everything and never starves the decode batch."""
    inst = make_hybrid(model, token_budget=24, chunk_tokens=16)
    reqs = []
    try:
        for seed in (20, 21):
            r = _req(out_tokens=3)
            reqs.append(r)
            inst.submit(r, _tokens(seed))
        assert inst.drain(120.0)
    finally:
        inst.shutdown()
    assert len(inst.finished) == 2
    assert all(len(j.emitted) == 4 for j in inst.finished_jobs)
    assert all(r.mean_tpot is not None for r in reqs)
