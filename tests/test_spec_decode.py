"""Speculative decoding inside the batched ragged decode runtime: greedy
draft-then-verify must be BIT-IDENTICAL to plain decoding (the whole premise
of fig27's speedup claim), eviction mid-draft must resume cleanly, jit
recompiles stay bounded with the extra k+1 verify shape family, and
``spec_decode=False`` leaves every plain-path artifact untouched — counters
zero, no verify traces, sim outputs byte-equal to a run that never heard of
the feature."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_tiny_config
from repro.core.predictor import DecodeStepPredictor, expected_accept_tokens
from repro.core.request import Request
from repro.models import init_params
from repro.models.model import decode_step, prefill
from repro.serving.decode_instance import DecodeInstance, DecodeJob

CFG = dataclasses.replace(get_tiny_config("llama3_8b"),
                          num_layers=2, d_model=128, d_ff=256)
MAX_SEQ = 256
K = 4


@pytest.fixture(scope="module")
def model():
    return init_params(CFG, jax.random.PRNGKey(0))


def _handoff(params, n, seed):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, CFG.vocab_size, (1, n)), jnp.int32)
    logits, cache = prefill(params, CFG, {"tokens": toks}, max_seq=MAX_SEQ)
    return int(jnp.argmax(logits, -1)[0]), \
        {"k": cache["k"], "v": cache["v"], "pos": cache["pos"]}


# Eager `decode_step` re-traces its lax.scan every call, and scan's dispatch
# cache keys the body jaxpr by identity — so an eager replay loop triggers a
# full XLA compile per token. Jit once at module scope instead: one compile,
# then cached calls (also what the dense single-stream worker does).
_plain_step = jax.jit(lambda p, t, c: decode_step(p, CFG, t, c))


def _replay(params, first, cache, n_tokens):
    """Plain sequential greedy decode: the bit-parity reference."""
    tok = jnp.asarray([first], jnp.int32)
    c = dict(cache)
    out = []
    for _ in range(n_tokens):
        logits, c = _plain_step(params, tok, c)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


def _job(first, cache, out_tokens, tbt=100.0):
    req = Request(num_tokens=int(cache["pos"]), slo=100.0, arrival=0.0,
                  output_tokens=out_tokens, tbt_slo=tbt)
    return DecodeJob(request=req, cache=dict(cache), first_token=first)


def _corpus(params, streams, n_tokens):
    """Reference continuations keyed by first token (the drafters' corpus
    AND the parity oracle). Distinct first tokens are asserted because the
    drafters dispatch on history[0]."""
    by_first = {f: _replay(params, f, c, n_tokens) for f, c in streams}
    assert len(by_first) == len(streams), "first tokens must be distinct"
    return by_first


def _oracle(by_first):
    def draft(rid, history, k):
        seq = by_first[history[0]]
        done = len(history) - 1
        return seq[done:done + k]
    return draft


def _adversarial(by_first):
    def draft(rid, history, k):
        seq = by_first[history[0]]
        done = len(history) - 1
        # first draft position always != the true greedy token: accept
        # rate is exactly 0, the worst case for speculation
        return [(seq[done] + 1) % CFG.vocab_size] if done < len(seq) else []
    return draft


def _run_spec(params, streams, out_tokens, *, draft_fn, n_slots=None,
              **kw):
    inst = DecodeInstance(params, CFG, decode_tokens=out_tokens,
                          decode_max_batch=n_slots or len(streams),
                          kv_block_size=64, spec_decode=True, draft_k=K,
                          draft_fn=draft_fn, **kw)
    jobs = [_job(f, c, out_tokens) for f, c in streams]
    try:
        for j in jobs:
            inst.submit(j)
        assert inst.drain(120.0)
    finally:
        inst.shutdown()
    return inst, jobs


# --- bit parity --------------------------------------------------------------


@pytest.mark.parametrize("seeds,prompts", [
    ((300, 301, 302, 303), (32, 48, 80, 100)),   # full 4-slot bucket
    ((310, 311), (48, 64)),                      # 2-slot bucket
    ((320,), (40,)),                             # degenerate single stream
])
def test_oracle_spec_trajectory_bitmatches_plain_replay(model, seeds,
                                                        prompts):
    """Accept-everything regime: every verify step commits k+1 tokens, and
    the FULL emitted trajectory (job.history carries every token) is
    bit-equal to the plain sequential replay — speculation changes the
    schedule, never the tokens."""
    params = model
    out = 12
    streams = [_handoff(params, n, seed=s) for s, n in zip(seeds, prompts)]
    by_first = _corpus(params, streams, out + K)
    inst, jobs = _run_spec(params, streams, out, draft_fn=_oracle(by_first))

    for j, (f, _) in zip(jobs, streams):
        want = by_first[f]
        assert j.tokens_done == out
        assert j.history == [f] + want[:out]      # every token, in order
        assert j.next_token == want[out - 1]
    # ...and speculation actually happened: drafts accepted, fewer verify
    # steps than tokens (each commits up to k+1)
    assert inst.draft_accepted > 0
    assert inst.draft_accepted == inst.draft_proposed   # oracle never misses
    assert inst.spec_steps > 0
    # per-row tokens/step must exceed 1 (multi-token commits measured by the
    # satellite accounting: len(tbt_samples) counts accepted tokens,
    # row_steps counts (stream, step) pairs)
    assert len(inst.tbt_samples) == len(streams) * out
    assert len(inst.tbt_samples) / inst.row_steps > 1.5


def test_adversarial_spec_bitmatches_and_throttles(model):
    """Reject-everything regime: output still bit-equal to plain decoding,
    zero drafts accepted, and the accept-rate EMA throttles drafting so most
    steps fall back to the plain batched shape."""
    params = model
    out = 24
    streams = [_handoff(params, n, seed=330 + i)
               for i, n in enumerate((32, 48, 80, 100))]
    by_first = _corpus(params, streams, out + K)
    inst, jobs = _run_spec(params, streams, out,
                           draft_fn=_adversarial(by_first))

    for j, (f, _) in zip(jobs, streams):
        assert j.history == [f] + by_first[f][:out]
        assert j.next_token == by_first[f][out - 1]
    assert inst.draft_accepted == 0
    assert inst.draft_proposed > 0               # it did probe
    # EMA throttle: after the first rejections, drafting drops to the
    # 1-in-spec_probe_period probe cadence — strictly fewer verify-shaped
    # steps than total steps
    assert 0 < inst.spec_steps < inst.steps
    # every accepted token is the verify row's own greedy argmax: exactly
    # one per row per step
    assert len(inst.tbt_samples) == len(streams) * out
    assert len(inst.tbt_samples) / inst.row_steps == pytest.approx(1.0)


def test_default_ngram_drafter_bitparity(model):
    """The self-drafting n-gram drafter (draft_fn=None) on pseudorandom
    sequences: whatever it proposes — usually nothing, occasionally a bogus
    suffix match — the greedy verify keeps output bit-identical."""
    params = model
    out = 10
    streams = [_handoff(params, n, seed=340 + i)
               for i, n in enumerate((32, 48))]
    by_first = _corpus(params, streams, out)
    inst, jobs = _run_spec(params, streams, out, draft_fn=None)
    for j, (f, _) in zip(jobs, streams):
        assert j.history == [f] + by_first[f][:out]
        assert j.next_token == by_first[f][out - 1]
    assert inst.draft_accepted <= inst.draft_proposed


def test_mixed_accept_streams_in_one_batch(model):
    """One batch mixing an oracle-drafted stream with adversarially-drafted
    ones: per-row acceptance bookkeeping keeps them independent — the lucky
    stream advances multi-token while the others advance one, all
    bit-equal."""
    params = model
    out = 12
    streams = [_handoff(params, n, seed=350 + i)
               for i, n in enumerate((32, 48, 64))]
    by_first = _corpus(params, streams, out + K)
    lucky_first = streams[0][0]
    oracle, adversarial = _oracle(by_first), _adversarial(by_first)

    def mixed(rid, history, k):
        if history[0] == lucky_first:
            return oracle(rid, history, k)
        return adversarial(rid, history, k)

    inst, jobs = _run_spec(params, streams, out, draft_fn=mixed)
    for j, (f, _) in zip(jobs, streams):
        assert j.history == [f] + by_first[f][:out]
    assert inst.draft_accepted > 0               # the lucky stream's commits
    assert jobs[0].request.finish_time <= jobs[-1].request.finish_time


# --- eviction / resume -------------------------------------------------------


def test_eviction_mid_draft_resumes_bitexact(model):
    """Preemption-as-eviction with speculation live: a tight-TBT arrival
    displaces a resident stream between verify steps; the evicted stream's
    tokens_done / next_token / history all sit at a mid-draft position (not
    a k+1 multiple), and on resume it still decodes exactly its replay."""
    params = model
    pred = DecodeStepPredictor(prior=lambda b, c: 1e-4, ema_alpha=0.0)
    loose_s = [_handoff(params, 32, seed=360), _handoff(params, 48, seed=361)]
    tight_s = _handoff(params, 40, seed=362)
    by_first = _corpus(params, loose_s + [tight_s], 40 + K)
    inst = DecodeInstance(params, CFG, decode_tokens=8, decode_max_batch=2,
                          kv_block_size=64, policy="s-edf",
                          step_predictor=pred, spec_decode=True, draft_k=K,
                          draft_fn=_adversarial(by_first))
    # adversarial drafts keep steps single-token (one token per step, like
    # the plain preemption test) so the slot contention window stays open
    # long enough for the tight stream to arrive mid-decode
    loose = [_job(f, c, 40, tbt=100.0) for f, c in loose_s]
    tight = _job(*tight_s, 6, tbt=2.0)
    try:
        for j in loose:
            inst.submit(j)
        deadline = time.monotonic() + 30.0
        while inst.steps < 2 and time.monotonic() < deadline:
            time.sleep(0.002)
        inst.submit(tight)
        assert inst.drain(120.0)
    finally:
        inst.shutdown()
    assert inst.preemptions >= 1
    assert sum(j.request.decode_preemptions for j in loose) >= 1
    assert [j.tokens_done for j in loose] == [40, 40]
    assert tight.tokens_done == 6
    for j, (f, _) in zip(loose, loose_s):
        # eviction preserved the stream bit-exactly THROUGH the spec path:
        # full trajectory, not just the last token
        assert j.history == [f] + by_first[f][:40]
    assert tight.next_token == by_first[tight_s[0]][5]


def test_resumed_midstream_job_drafts_from_prior_history(model):
    """A job migrated in mid-stream (tokens_done > 0, no history yet) must
    rebuild drafting state from its resume point and stay bit-exact."""
    params = model
    f, c = _handoff(params, 48, seed=370)
    want = _replay(params, f, c, 8 + K)
    done = _replay(params, f, c, 3)
    mid = dict(c)
    # rebuild the migrated-in cache at +3 tokens
    tok = jnp.asarray([f], jnp.int32)
    for _ in range(3):
        logits, mid = _plain_step(params, tok, mid)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    req = Request(num_tokens=48, slo=100.0, arrival=0.0, output_tokens=8,
                  tbt_slo=100.0)
    job = DecodeJob(request=req, first_token=f, tokens_done=3,
                    next_token=done[2],
                    cache={"k": mid["k"], "v": mid["v"], "pos": mid["pos"]})

    # oracle keyed on next_token: history restarts at the resume point
    def draft(rid, history, k):
        d = 3 + (len(history) - 1)               # tokens generated overall
        return want[d:d + k]

    inst = DecodeInstance(params, CFG, decode_tokens=8, decode_max_batch=2,
                          kv_block_size=64, spec_decode=True, draft_k=K,
                          draft_fn=draft)
    try:
        inst.submit(job)
        assert inst.drain(120.0)
    finally:
        inst.shutdown()
    assert job.tokens_done == 8
    assert job.next_token == want[7]
    assert inst.draft_accepted > 0               # the resume drafts landed


# --- compile discipline ------------------------------------------------------


def test_spec_recompiles_bounded_by_two_shape_families(model):
    """With speculation on, TWO step families exist — the plain S=1 ragged
    step (throttled fallback) and the S=k+1 verify step. Sweeping resident
    populations must stay within |batch buckets| x |width buckets| traces
    PER family."""
    params = model
    by_first = {}

    def flaky(rid, history, k):
        # alternate hit/miss per call so BOTH families get exercised at
        # several batch buckets without depending on EMA state
        seq = by_first[history[0]]
        done = len(history) - 1
        if done % 2 == 0:
            return seq[done:done + k]
        return [(seq[done] + 1) % CFG.vocab_size] if done < len(seq) else []

    # build every round's streams + reference corpus BEFORE the instance
    # exists: all host-side jax compiles happen with no worker thread alive
    rounds = []
    seed = 400
    for n_streams in (1, 2, 3, 5, 8):
        streams = []
        for _ in range(n_streams):
            f, c = _handoff(params, 32 + 16 * (seed % 2), seed)
            seed += 1
            streams.append((f, c))
        for f, c in streams:
            by_first[f] = _replay(params, f, c, 4 + K)
        rounds.append(streams)

    inst = DecodeInstance(params, CFG, decode_tokens=4, decode_max_batch=8,
                          kv_block_size=64, batch_buckets=(1, 2, 4, 8),
                          spec_decode=True, draft_k=K, draft_fn=flaky,
                          spec_throttle=0.0)    # never throttle: keep probing
    try:
        for streams in rounds:
            jobs = [_job(f, c, 4) for f, c in streams]
            for j in jobs:
                inst.submit(j)
            assert inst.drain(120.0)
        n_widths = 1     # 32/48-token prompts + short targets: one 64 block
        assert 0 < inst.compile_cache_size() <= 2 * 4 * n_widths
    finally:
        inst.shutdown()


# --- spec off is byte-identical off ------------------------------------------


def test_spec_off_leaves_plain_path_untouched(model):
    """The default-off contract: a plain instance carries zero speculative
    state — no verify traces compiled, counters zero, no history built —
    so every pre-existing baseline (fig9/18-26) is untouched by
    construction."""
    params = model
    f, c = _handoff(params, 48, seed=380)
    want = _replay(params, f, c, 6)
    inst = DecodeInstance(params, CFG, decode_tokens=6, decode_max_batch=2,
                          kv_block_size=64)
    assert inst.spec_decode is False             # the default
    job = _job(f, c, 6)
    try:
        inst.submit(job)
        assert inst.drain(60.0)
    finally:
        inst.shutdown()
    assert job.next_token == want[-1]
    assert (inst.spec_steps, inst.draft_proposed, inst.draft_accepted) \
        == (0, 0, 0)
    assert job.history is None                   # plain path skips bookkeeping
    # only the plain family ever traced: same bound as the pre-spec suite
    assert 0 < inst.compile_cache_size() <= 4
    # per-row tokens/step is exactly 1.0 when off
    assert len(inst.tbt_samples) == inst.row_steps == 6


def test_spec_off_sim_is_byte_identical():
    """The sim-side contract: threading spec kwargs with spec off produces
    FLOAT-IDENTICAL results to a run that never passes them — the committed
    fig9/18-26 baselines cannot move."""
    from repro.sim.cluster import simulate_cluster
    from repro.traces.qwentrace import TraceConfig, generate

    cfg = TraceConfig(rate=8.0, duration=20.0, seed=3, output_mean=100.0)
    kw = dict(num_instances=2, decode_instances=2, decode_max_batch=8,
              decode_policy="s-edf")
    legacy = simulate_cluster("flowprefill", generate(cfg), **kw)
    explicit = simulate_cluster("flowprefill", generate(cfg),
                                spec_decode=False, draft_k=K,
                                spec_accept=0.9, **kw)
    for a, b in zip(legacy.requests, explicit.requests):
        assert a.mean_tpot == b.mean_tpot        # exact, not approx
        assert a.finish_time == b.finish_time
    assert legacy.tbt_attainment == explicit.tbt_attainment
    # the default Request/TraceConfig stamps are inert too
    assert Request(num_tokens=1, slo=1.0, arrival=0.0).spec_accept == 0.0
    assert TraceConfig().spec_accept_by_task is None
    r = generate(TraceConfig(rate=2.0, duration=5.0, seed=0))[0]
    assert r.spec_accept == 0.0


# --- the shared accept surface -----------------------------------------------


def test_expected_accept_tokens_surface():
    """The analytic E[tokens/step] the runtime EMA, scheduler pricing, and
    sim all share: exact at the endpoints, monotone in accept rate, capped
    at k+1."""
    assert expected_accept_tokens(0.0, K) == 1.0
    assert expected_accept_tokens(1.0, K) == K + 1
    assert expected_accept_tokens(0.5, 0) == 1.0
    # geometric-series closed form at a=0.5, k=2: 1 + 1/2 + 1/4
    assert expected_accept_tokens(0.5, 2) == pytest.approx(1.75)
    es = [expected_accept_tokens(a / 10, K) for a in range(11)]
    assert all(lo <= hi for lo, hi in zip(es, es[1:]))
    assert all(1.0 <= e <= K + 1 for e in es)
    # out-of-range inputs clamp instead of exploding
    assert expected_accept_tokens(-0.3, K) == 1.0
    assert expected_accept_tokens(1.7, K) == K + 1
