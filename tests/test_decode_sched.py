"""Decode-side S-EDF scheduling: priority edge cases (zero-slack ties,
doomed/no-SLO ordering), slot-capped admission + token-boundary preemption,
cost-gated migration (no thrash under full saturation), single-decode-instance
ClusterSim parity with a standalone DecodeSim replay, and the end-to-end
attainment wins the fig20 benchmark gates."""
import copy
import heapq
import itertools
from dataclasses import replace

import pytest

from repro.core.dispatch import (DecodeCandidate, DecodeLoad,
                                 plan_decode_migrations)
from repro.core.scheduler import (DecodeEntry, DecodeSchedulerCore,
                                  decode_sedf_priority)
from repro.sim import cluster as cl
from repro.sim.cluster import DecodeSim, simulate_cluster
from repro.sim.costmodel import (A800, LLAMA3_8B, MODEL_TP, DecodeCostModel)
from repro.traces.qwentrace import TraceConfig, generate

DEC_COST = DecodeCostModel(replace(LLAMA3_8B, tp=MODEL_TP["llama3-8b"]), A800)


# --- priority edge cases -----------------------------------------------------


def test_decode_sedf_priority_ordering():
    """Feasible ranks above no-SLO (priority 0) ranks above doomed; among
    feasible, earlier decode deadline wins."""
    t_step = 0.01
    tight = DecodeEntry(key=1, remaining_tokens=10, deadline=5.0, order=0)
    loose = DecodeEntry(key=2, remaining_tokens=10, deadline=50.0, order=1)
    no_slo = DecodeEntry(key=3, remaining_tokens=10,
                         deadline=float("inf"), order=2)
    doomed = DecodeEntry(key=4, remaining_tokens=1000, deadline=5.0, order=3)
    now = 1.0
    p = {e.key: decode_sedf_priority(e, now, t_step)
         for e in (tight, loose, no_slo, doomed)}
    assert p[1] > p[2] > p[3] > p[4]
    assert p[3] == 0.0                       # inf deadline -> neutral
    assert p[4] < 0.0                        # negative slack -> doomed
    core = DecodeSchedulerCore(policy="s-edf")
    ranked = core.rank([doomed, no_slo, loose, tight], now, t_step)
    assert [e.key for e in ranked] == [1, 2, 3, 4]


def test_zero_slack_tie_is_deterministic():
    """slack == 0 exactly counts as feasible (sgn(0) = +1), and equal
    deadlines tie-break by admission order, so repeated select_batch calls
    are stable (no flapping between equal-priority streams)."""
    now, t_step = 2.0, 0.05
    # deadline - now - remaining * t_step == 0 for both
    a = DecodeEntry(key=10, remaining_tokens=20.0, deadline=3.0, order=0)
    b = DecodeEntry(key=11, remaining_tokens=20.0, deadline=3.0, order=1)
    assert decode_sedf_priority(a, now, t_step) == \
        decode_sedf_priority(b, now, t_step) > 0
    core = DecodeSchedulerCore(policy="s-edf", preempt=True)
    for _ in range(3):
        batch, preempted = core.select_batch([a, b], {10}, 1, now, t_step)
        assert batch == [10]                 # earlier order keeps the slot
        assert preempted == []


def test_select_batch_admission_and_preemption():
    now, t_step = 0.0, 0.01
    tight = DecodeEntry(key=1, remaining_tokens=10, deadline=1.0, order=2)
    loose = DecodeEntry(key=2, remaining_tokens=10, deadline=90.0, order=0)
    slack = DecodeEntry(key=3, remaining_tokens=10, deadline=99.0, order=1)
    entries = [tight, loose, slack]
    core = DecodeSchedulerCore(policy="s-edf", preempt=True)
    batch, preempted = core.select_batch(entries, {2, 3}, 2, now, t_step)
    assert batch == [1, 2]                   # tight displaces the slack-rich
    assert preempted == [3]
    core_np = DecodeSchedulerCore(policy="s-edf", preempt=False)
    batch, preempted = core_np.select_batch(entries, {2, 3}, 2, now, t_step)
    assert set(batch) == {2, 3} and preempted == []   # residents keep slots
    fcfs = DecodeSchedulerCore(policy="fcfs", preempt=True)
    batch, preempted = fcfs.select_batch(entries, {2, 3}, 2, now, t_step)
    assert set(batch) == {2, 3} and preempted == []   # arrival order rules
    # unbounded: everyone admitted, never preempted
    batch, preempted = core.select_batch(entries, {2, 3}, 0, now, t_step)
    assert set(batch) == {1, 2, 3} and preempted == []


# --- migration planner gates -------------------------------------------------


def _load(iid, waiting, ctx_per=600.0, resident=1, max_batch=1):
    n = resident + waiting
    return DecodeLoad(instance_id=iid, n_resident=resident,
                      n_waiting=waiting, ctx_tokens=ctx_per * n,
                      max_batch=max_batch, step_time=DEC_COST.step_time)


def test_migration_empty_plan_when_every_instance_saturated():
    """The no-thrash gate: a pool in which every instance is past the knee
    must produce an EMPTY plan — migrating between saturated instances only
    pays KV-transfer cost without buying slack."""
    loads = [_load(i, waiting=6) for i in range(4)]
    cands = [DecodeCandidate(key=k, context_tokens=600.0,
                             remaining_tokens=200.0, deadline=10.0)
             for k in range(3)]
    plan = plan_decode_migrations(loads[0], cands, loads, now=0.0,
                                  transfer_time=DEC_COST.kv_transfer_time)
    assert plan == []


def test_migration_moves_queued_stream_to_idle_instance():
    src = _load(0, waiting=6)
    dst = _load(1, waiting=0, resident=0)
    cand = DecodeCandidate(key=7, context_tokens=600.0,
                           remaining_tokens=200.0, deadline=10.0)
    plan = plan_decode_migrations(src, [cand], [src, dst], now=0.0,
                                  transfer_time=DEC_COST.kv_transfer_time)
    assert len(plan) == 1
    key, dst_id, xfer = plan[0]
    assert (key, dst_id) == (7, 1)
    assert xfer == DEC_COST.kv_transfer_time(600.0) > 0


def test_migration_gates_on_cap_cost_and_doom():
    src = _load(0, waiting=6)
    dst = _load(1, waiting=0, resident=0)
    good = dict(context_tokens=600.0, remaining_tokens=200.0, deadline=10.0)
    # migration cap reached -> skipped
    capped = DecodeCandidate(key=1, migrations=1, **good)
    assert plan_decode_migrations(src, [capped], [src, dst], 0.0) == []
    # already doomed (negative budget) -> transfer cannot save it
    doomed = DecodeCandidate(key=2, context_tokens=600.0,
                             remaining_tokens=200.0, deadline=-1.0)
    assert plan_decode_migrations(src, [doomed], [src, dst], 0.0) == []
    # prohibitive KV-handoff cost -> benefit gate rejects the move
    slow_link = lambda ctx: 1e6                          # noqa: E731
    assert plan_decode_migrations(src, [DecodeCandidate(key=3, **good)],
                                  [src, dst], 0.0,
                                  transfer_time=slow_link) == []
    # one pass cannot dump the whole queue onto a single small target: the
    # running dst tally saturates it after a few moves
    cands = [DecodeCandidate(key=10 + i, **good) for i in range(6)]
    plan = plan_decode_migrations(src, cands, [src, dst], 0.0)
    assert 0 < len(plan) < len(cands)


# --- DecodeSim: capped batch, preemption, parity -----------------------------


def _mk_request(rid_tokens=512, out=64, tbt=0.05, arrival=0.0):
    from repro.core.request import Request
    return Request(num_tokens=rid_tokens, slo=1.0, arrival=arrival,
                   output_tokens=out, tbt_slo=tbt)


def _drive(dec, heap, joins):
    """Replay (time, request) joins through a standalone DecodeSim heap."""
    seq = itertools.count(10 ** 9)
    JOIN = -1
    for t, r in joins:
        heapq.heappush(heap, (t, next(seq), JOIN, r))
    now = 0.0
    while heap:
        now, _, kind, payload = heapq.heappop(heap)
        if kind == JOIN:
            dec.join(payload, now)
        else:
            dec.on_decode_done(payload, now)
    return now


def test_decode_preemption_displaces_slack_rich_resident():
    """Slot cap 1: a loose-TBT stream is decoding; a tight-TBT stream joins
    and must displace it at the (fluid) token boundary, finish first, and the
    displaced stream must still complete with its progress preserved."""
    heap = []
    dec = DecodeSim(DEC_COST, heap, itertools.count(), max_batch=1,
                    scheduler=DecodeSchedulerCore(policy="s-edf"))
    loose = _mk_request(out=400, tbt=10.0)
    tight = _mk_request(out=50, tbt=0.02)
    end = _drive(dec, heap, [(0.0, loose), (1.0, tight)])
    assert dec.preemptions >= 1
    assert loose.decode_preemptions >= 1 and tight.decode_preemptions == 0
    assert tight.finish_time < loose.finish_time <= end
    assert tight.tbt_met and loose.tbt_met
    # FCFS on the same schedule: the tight stream waits out the whole loose
    # decode and blows its TBT SLO
    heap2 = []
    dec2 = DecodeSim(DEC_COST, heap2, itertools.count(), max_batch=1,
                     scheduler=DecodeSchedulerCore(policy="fcfs"))
    loose2, tight2 = _mk_request(out=400, tbt=10.0), _mk_request(out=50,
                                                                 tbt=0.02)
    _drive(dec2, heap2, [(0.0, loose2), (1.0, tight2)])
    assert dec2.preemptions == 0
    assert not tight2.tbt_met


def test_unbounded_sedf_is_plain_processor_sharing():
    """With no slot cap the scheduler has nothing to decide: s-edf and fcfs
    decode runs must be event-for-event identical (also pins the refactor's
    bit-identity with the original unbounded DecodeSim)."""
    reqs = generate(TraceConfig(rate=6, duration=20, seed=2,
                                output_mean=128, tbt_slo=0.05))
    runs = {}
    for pol in ("fcfs", "s-edf"):
        res = simulate_cluster("flowprefill", reqs, num_instances=2,
                               dispatch="least-loaded", decode_instances=2,
                               decode_policy=pol, decode_max_batch=0)
        runs[pol] = [(r.rid, r.first_token_time, r.finish_time, r.mean_tpot)
                     for r in res.requests]
        assert res.decode_preemptions == 0
    assert runs["fcfs"] == runs["s-edf"]


@pytest.mark.parametrize("policy", ["fcfs", "s-edf"])
def test_one_decode_instance_cluster_parity_with_standalone_sim(monkeypatch,
                                                                policy):
    """ClusterSim with ONE decode instance must reproduce a standalone
    DecodeSim fed the same join schedule exactly — the cluster layer adds
    routing, not decode semantics (checked at decode_max_batch > 1 for both
    admission policies: the slot-capped continuous-batching model the real
    runtime now implements)."""
    joins = []

    class Recorder(DecodeSim):
        def join(self, req, now):
            joins.append((now, req.rid))
            super().join(req, now)

    monkeypatch.setattr(cl, "DecodeSim", Recorder)
    reqs = generate(TraceConfig(rate=5, duration=20, seed=4,
                                output_mean=128, tbt_slo=0.02))
    res = simulate_cluster("flowprefill", reqs, num_instances=2,
                           dispatch="least-loaded", decode_instances=1,
                           decode_policy=policy, decode_max_batch=4)
    assert res.decoded == len(reqs) and joins
    cluster_out = {r.rid: (r.finish_time, r.mean_tpot) for r in res.requests}

    # standalone replay: fresh request copies, the recorded join schedule
    by_rid = {r.rid: r for r in (copy.copy(r) for r in reqs)}
    for r in by_rid.values():
        r.decode_start = None
        r.finish_time = None
        r.mean_tpot = None
        r.decode_preemptions = 0
    heap = []
    dec = DecodeSim(DEC_COST, heap, itertools.count(10 ** 6), max_batch=4,
                    scheduler=DecodeSchedulerCore(
                        policy=policy, preempt=(policy == "s-edf")))
    _drive(dec, heap, [(t, by_rid[rid]) for t, rid in joins])
    assert len(dec.finished) == len(reqs)
    for r in by_rid.values():
        assert (r.finish_time, r.mean_tpot) == cluster_out[r.rid]


def test_single_decode_migration_is_a_noop():
    """decode_migration with one decode instance has no target: results must
    be identical to migration off (and count zero migrations)."""
    reqs = generate(TraceConfig(rate=6, duration=15, seed=6,
                                output_mean=128, tbt_slo=0.02))
    kw = dict(num_instances=2, dispatch="least-loaded", decode_instances=1,
              decode_policy="s-edf", decode_max_batch=4)
    off = simulate_cluster("flowprefill", reqs, decode_migration=False, **kw)
    on = simulate_cluster("flowprefill", reqs, decode_migration=True, **kw)
    assert on.migrations == 0
    assert [(r.finish_time, r.mean_tpot) for r in on.requests] == \
        [(r.finish_time, r.mean_tpot) for r in off.requests]


# --- cluster-level wins (the fig20 claims, one point each) -------------------


TBT_BY_TASK = {"text": 0.015, "image": 0.03, "search": 0.1, "file": 0.1}


def _fig20_run(policy, migration, rate=10, pool=("a800",) * 4):
    reqs = generate(TraceConfig(rate=rate, duration=40, seed=3,
                                output_mean=256, tbt_slo=0.05,
                                tbt_slo_by_task=TBT_BY_TASK))
    return simulate_cluster("flowprefill", reqs, hardware=list(pool),
                            decode_hardware=list(pool),
                            decode_instances=len(pool),
                            dispatch="capacity-weighted",
                            decode_affinity=True, decode_max_batch=16,
                            decode_policy=policy, decode_migration=migration)


def test_sedf_decode_beats_fcfs_on_mixed_tbt_slos():
    """The fig20 homogeneous-pool claim at one operating point: slack-aware
    admission on a mixed tight/loose TBT workload beats FCFS decode by a wide
    margin on e2e attainment."""
    fcfs = _fig20_run("fcfs", False)
    sedf = _fig20_run("s-edf", False)
    assert sedf.decode_preemptions > 0
    assert sedf.attainment == pytest.approx(fcfs.attainment, abs=0.02)
    assert sedf.e2e_attainment >= fcfs.e2e_attainment + 0.15
    assert sedf.tbt_attainment >= fcfs.tbt_attainment + 0.15


def test_migration_recovers_static_pairing_imbalance_on_hetero_pool():
    """The fig20 hetero claim: under static paired PD wiring on 2xA800+2xA100
    migration fires, is bounded per stream (cost-gated), and does not hurt
    e2e attainment at the operating point where it triggers."""
    pool = ("a800", "a800", "a100", "a100")
    sedf = _fig20_run("s-edf", False, rate=6, pool=pool)
    mig = _fig20_run("s-edf", True, rate=6, pool=pool)
    assert mig.migrations > 0
    assert all(r.decode_migrations <= 1 for r in mig.requests)
    assert mig.e2e_attainment >= sedf.e2e_attainment


# --- threaded runtime (stubbed decode step: no model, real threads) ----------


def _install_stub(monkeypatch, step_seconds=0.02):
    """Replace the jitted decode step with a sleepy stub so queueing and
    token-boundary preemption are observable without a model."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.serving import decode_instance as di

    def stub(params, cfg, tok, cache):
        time.sleep(step_seconds)
        return jnp.zeros((1, 4)), cache

    monkeypatch.setattr(di, "decode_step", stub)
    monkeypatch.setattr(jax, "jit", lambda f: f)
    return di


def test_runtime_decode_instance_sedf_preempts_at_token_boundary(monkeypatch):
    import time

    from repro.core.predictor import DecodeStepPredictor

    di = _install_stub(monkeypatch)
    # ema_alpha=0 pins the calibration scale: under machine load the sleepy
    # stub's measured steps overshoot, which would inflate t_step until the
    # tight stream ranks as doomed (doomed streams never preempt)
    inst = di.DecodeInstance(
        None, None, decode_tokens=15, policy="s-edf",
        step_predictor=DecodeStepPredictor(prior=lambda b, c: 0.02,
                                           ema_alpha=0.0))
    try:
        # tight = urgent but FEASIBLE (a doomed stream must never preempt:
        # ~30ms/token calibrated estimate x 15 tokens needs < the TBT budget)
        loose = _mk_request(out=0, tbt=10.0)
        tight = _mk_request(out=0, tbt=0.08)
        inst.submit(di.DecodeJob(request=loose, cache={}, first_token=0))
        time.sleep(0.08)                     # let the loose stream start
        inst.submit(di.DecodeJob(request=tight, cache={}, first_token=0))
        assert inst.drain(30.0)
        assert inst.preemptions >= 1
        assert loose.decode_preemptions >= 1
        assert tight.finish_time < loose.finish_time
        assert loose.mean_tpot is not None and tight.mean_tpot is not None
        assert loose.output_tokens == tight.output_tokens == 15
        assert len(inst.tbt_samples) == 30   # every token decoded exactly once
    finally:
        inst.shutdown()


def test_runtime_proxy_migrates_queued_decodes(monkeypatch):
    from types import SimpleNamespace

    from repro.serving.proxy import Proxy

    di = _install_stub(monkeypatch)
    insts = [di.DecodeInstance(None, None, decode_tokens=10, policy="s-edf")
             for _ in range(2)]
    prefill_stub = SimpleNamespace(scheduler=None, scheduling_rounds=0,
                                   blocking_stats=SimpleNamespace(mean=0.0))
    proxy = Proxy([prefill_stub], insts,
                  decode_cost=DEC_COST, decode_migration=True)
    try:
        reqs = [_mk_request(rid_tokens=64, out=0, tbt=0.05) for _ in range(6)]
        for r in reqs:
            insts[0].submit(di.DecodeJob(request=r, cache={}, first_token=0))
        moved = proxy.rebalance_decodes()
        assert moved > 0 and proxy.decode_migrations == moved
        assert insts[1].pending() > 0        # queued streams actually moved
        assert all(inst.drain(30.0) for inst in insts)
        assert all(r.finish_time is not None for r in reqs)
        assert sum(r.decode_migrations for r in reqs) == moved
        assert proxy.report()["decode_migrations"] == moved
    finally:
        for inst in insts:
            inst.shutdown()
