"""Tiered KV cache (HBM -> host -> disk): tier-invariant property suite and
copy-engine fault injection (property style — hypothesis-backed when
installed, seeded fallback otherwise).

The ISSUE-level properties:

  1. tier-adjusted conservation after EVERY operation: free + live + cached
     + in_flight == num_blocks (HBM blocks disjoint across states), a chain
     key resides in at most one of {trie, in-flight, host, disk}, and both
     cold tiers respect their capacities (`TieredBlockManager.check`);
  2. pinned (refcount > 0) blocks are never demoted — demotion's only
     source is the LRU of refcount-0 CACHED blocks;
  3. promoted KV bit-matches the demoted KV (checksum-verified round trip
     through host numpy storage and the disk .npz spill);
  4. ``host_blocks=0`` reduces exactly to the parent `PrefixBlockManager`
     (the single-tier default path stays bit-identical);
  5. the copy engine fails CLOSED: a corrupted or lost cold copy aborts the
     promotion and drops the entry (recompute fallback, never stale KV); a
     promotion losing a race with a twin registration frees its reserved
     block; shutdown with transfers in flight drains cleanly — every
     reserved block settles back to the pool, nothing leaks.
"""
import numpy as np
import pytest

from repro.core.prefixcache import PrefixBlockManager, chain_extend
from repro.core.tieredcache import (TIER_HOST, BlockCopyEngine,
                                    TieredBlockManager)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

# deterministic chain universe: 4 disjoint chains + 2 diverging after 3
_CHAINS = [chain_extend((), range(10), salt=s) for s in range(4)]
_CHAINS += [chain_extend(_CHAINS[0][:3], range(6), salt=40 + s)
            for s in range(2)]


# --- host_blocks=0 reduces to the parent --------------------------------------

def _drive(mgr, ops):
    """Apply an op sequence; return the observable outcome trace."""
    trace, held, sid = [], {}, 0
    for kind, chain, nblocks in ops:
        keys = _CHAINS[chain][:nblocks]
        if kind == "acquire":
            try:
                hit = mgr.acquire(sid, keys, nblocks)
                held[sid] = keys
                trace.append(("hit", hit, tuple(mgr.blocks_of(sid))))
                sid += 1
            except MemoryError:
                trace.append(("full",))
        elif kind == "commit" and held:
            k = next(iter(held))
            trace.append(("commit", mgr.commit(k, held.pop(k))))
        elif kind == "release" and held:
            k = next(iter(held))
            held.pop(k)
            mgr.release(k)
            trace.append(("release", k))
        elif kind == "probe":
            trace.append(("probe", mgr.probe_len(keys)))
        mgr.check()
    trace.append(("free", mgr.free_blocks, mgr.cached_blocks,
                  mgr.evictions))
    return trace


def test_host_zero_is_bitwise_the_parent():
    """TieredBlockManager(host_blocks=0) must be observationally identical
    to PrefixBlockManager on any op sequence: same hits, same block ids,
    same eviction/free/cached counters, and the cold tiers stay empty —
    the single-tier default path is bit-identical by construction."""
    rng = np.random.default_rng(7)
    ops = [(["acquire", "commit", "release", "probe"][rng.integers(0, 4)],
            int(rng.integers(0, len(_CHAINS))), int(rng.integers(1, 9)))
           for _ in range(60)]
    a = PrefixBlockManager(12)
    b = TieredBlockManager(12, host_blocks=0)
    assert _drive(a, ops) == _drive(b, ops)
    assert b.host_entries == 0 and b.disk_entries == 0 and b.demotions == 0


# --- tier conservation under random interleavings -----------------------------

def run_tier_property_case(rng):
    """Random acquire/share/commit/release/probe/promote interleavings on a
    small pool with host + disk tiers; `check()` (conservation + key
    exclusivity + capacity bounds) asserted after EVERY op, and pinned
    chains must keep their pinned hit prefix WARM while held (property 2 —
    demotion's only source is the refcount-0 LRU)."""
    mgr = TieredBlockManager(int(rng.integers(6, 14)),
                             host_blocks=int(rng.integers(1, 10)),
                             disk_blocks=int(rng.integers(0, 8)))
    held = {}                                  # sid -> (keys, pinned hit)
    sid = 0
    for _ in range(int(rng.integers(10, 60))):
        kind = ["acquire", "share", "commit", "release", "promote", "abort",
                "probe"][rng.integers(0, 7)]
        keys = _CHAINS[rng.integers(0, len(_CHAINS))][
            :int(rng.integers(1, 10))]
        if kind == "acquire":
            try:
                hit = mgr.acquire(sid, keys, len(keys))
                held[sid] = (keys, hit)
                sid += 1
            except MemoryError:
                pass
        elif kind == "share" and held:
            # completion: register the computed chain, then drop the pins —
            # its blocks park refcount-0 in the LRU (demotable from now on)
            k = next(iter(held))
            mgr.register(k, held.pop(k)[0])
            mgr.release(k)
        elif kind == "commit" and held:
            k = next(iter(held))
            mgr.commit(k, held.pop(k)[0])
        elif kind == "release" and held:
            k = next(iter(held))
            held.pop(k)
            mgr.release(k)
        elif kind == "promote":
            for key, _b, _t in mgr.promote_begin(
                    keys, max_blocks=int(rng.integers(1, 5))):
                if rng.random() < 0.7:
                    mgr.promote_commit(key)
                else:
                    mgr.promote_abort(key, corrupt=bool(rng.random() < 0.3))
        elif kind == "abort":
            # begin with no commit: abort everything (timeout path)
            for key, _b, _t in mgr.promote_begin(keys):
                mgr.promote_abort(key)
        elif kind == "probe":
            th = mgr.probe_tiers(keys)
            assert th.total_blocks <= len(keys)
        mgr.check()
        # a held seq's PINNED prefix (the acquire-time hit) stays warm: its
        # blocks are refcount > 0, so eviction/demotion can never take them
        for hkeys, hit in held.values():
            for hk in hkeys[:hit]:
                assert hk in mgr._trie, "pinned chain key left the trie"
                assert hk not in mgr._host and hk not in mgr._disk, \
                    "pinned chain key was demoted"
    for k in list(held):
        mgr.release(k)
    mgr.check()
    assert mgr.live_blocks == 0
    assert mgr.in_flight == 0


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=60, deadline=None)
    def test_tier_conservation_properties(seed):
        run_tier_property_case(np.random.default_rng(seed))
else:                                                 # pragma: no cover
    @pytest.mark.parametrize("seed", [0, 1, 2, 7, 13, 42, 99, 123, 2024,
                                      31337])
    def test_tier_conservation_properties(seed):
        run_tier_property_case(np.random.default_rng(seed))


# --- deterministic tier-lifecycle cases ---------------------------------------

def _cache_chain(mgr, sid, keys):
    """Compute-and-share one chain: acquire, register, release — its blocks
    park refcount-0 in the LRU (the evictable/demotable state)."""
    mgr.acquire(sid, keys, len(keys))
    mgr.register(sid, keys)
    mgr.release(sid)


def _fill_and_evict(mgr, n_chains=4, n=6):
    """Register n_chains chains then overflow the pool so they demote."""
    for c in range(n_chains):
        _cache_chain(mgr, c, _CHAINS[c][:n])
        mgr.check()


def test_demotion_cascade_host_to_disk_to_drop():
    """HBM eviction enters the host tier; host overflow spills to disk;
    disk overflow drops — each stage observable in the counters and each
    key findable in exactly one tier."""
    mgr = TieredBlockManager(6, host_blocks=4, disk_blocks=4)
    _fill_and_evict(mgr, n_chains=4, n=6)
    # 4 chains x 6 blocks through a 6-block pool: 18 evictions demoted,
    # host holds the 4 freshest, disk the 4 behind, the rest dropped
    assert mgr.demotions == 18
    assert mgr.host_entries == 4 and mgr.disk_entries == 4
    assert mgr.spills >= 4 and mgr.tier_drops == mgr.demotions - 8
    th = mgr.probe_tiers(_CHAINS[3][:6])
    assert th.hbm_blocks + th.cold_blocks == 6     # freshest chain survives


def test_promotion_rewarm_and_budget():
    """A fully-cold chain promotes back to warm; warm keys are skipped for
    free and `max_blocks` counts only COLD reservations."""
    mgr = TieredBlockManager(8, host_blocks=16)
    keys = _CHAINS[1][:6]
    _cache_chain(mgr, 0, keys)
    # age the chain fully out of HBM
    mgr.acquire(1, _CHAINS[2][:8], 8)
    mgr.release(1)
    assert mgr.probe_len(keys) == 0
    th = mgr.probe_tiers(keys)
    assert (th.hbm_blocks, th.host_blocks) == (0, 6)
    got = mgr.promote_begin(keys, max_blocks=2)     # budget: 2 cold blocks
    assert [t for _, _, t in got] == [TIER_HOST, TIER_HOST]
    for key, _b, _t in got:
        mgr.promote_commit(key)
    mgr.check()
    assert mgr.probe_len(keys) == 2
    # second round: the 2 now-warm keys cost nothing against the budget
    got = mgr.promote_begin(keys, max_blocks=4)
    assert len(got) == 4
    for key, _b, _t in got:
        mgr.promote_commit(key)
    assert mgr.probe_len(keys) == 6
    assert mgr.promotions == 6


def test_promote_begin_pops_key_before_cascade_reuses_it():
    """The key being promoted is popped from its tier BEFORE `_take_block`
    runs the eviction cascade — so the cascade's own demotions can never
    age the in-flight key out from under the reservation."""
    mgr = TieredBlockManager(4, host_blocks=1)      # 1-entry host tier
    keys = _CHAINS[0][:4]
    _cache_chain(mgr, 0, keys)
    _cache_chain(mgr, 1, _CHAINS[1][:4])            # demotes all 4; host
                                                    # keeps only the last
    assert mgr.host_entries == 1
    (cold,) = list(mgr._host)
    got = mgr.promote_begin((cold,))
    # taking the HBM block demoted a CACHED block into the 1-slot host tier;
    # the promoted key was already safely in flight
    assert [k for k, _b, _t in got] == [cold]
    mgr.check()
    mgr.promote_commit(cold)
    mgr.check()
    assert cold in mgr._trie


def test_promote_abort_restores_tier_or_drops_corrupt():
    mgr = TieredBlockManager(4, host_blocks=8)
    keys = _CHAINS[2][:4]
    _cache_chain(mgr, 0, keys)
    mgr.acquire(1, _CHAINS[3][:4], 4)
    mgr.release(1)
    free0 = mgr.free_blocks + mgr.cached_blocks
    (k1, _b1, _t1), (k2, _b2, _t2) = mgr.promote_begin(keys, max_blocks=2)
    mgr.promote_abort(k1)                           # timeout: back to tier
    mgr.promote_abort(k2, corrupt=True)             # checksum fail: dropped
    mgr.check()
    assert k1 in mgr._host and k2 not in mgr._host
    assert mgr.free_blocks + mgr.cached_blocks == free0   # no leaked blocks
    assert mgr.promote_aborts == 2 and mgr.in_flight == 0


def test_promotion_loses_race_to_twin_registration():
    """While a key's promotion is in flight, a twin prompt computes and
    registers the same key: `promote_commit` must detect the race, keep the
    twin's live copy, and free the reserved block (return None)."""
    mgr = TieredBlockManager(8, host_blocks=8)
    keys = _CHAINS[1][:3]
    _cache_chain(mgr, 0, keys)
    mgr.acquire(1, _CHAINS[2][:8], 8)               # age the chain out
    mgr.release(1)
    got = mgr.promote_begin(keys, max_blocks=1)
    assert len(got) == 1
    key = got[0][0]
    # twin computes the same prefix from scratch and registers it first
    mgr.acquire(2, keys, 3)
    twin_block = mgr.blocks_of(2)[0]
    mgr.register(2, keys)
    mgr.release(2)
    assert mgr.promote_commit(key) is None          # race detected
    mgr.check()
    assert mgr._trie[key] == twin_block             # twin's copy is live
    assert mgr.in_flight == 0


# --- copy-engine fault injection ----------------------------------------------

def _tiered_cache(**kw):
    from repro.serving.kvcache import PagedKVCache
    kw.setdefault("host_cache_blocks", 16)
    return PagedKVCache(num_layers=2, num_blocks=4, block_size=4,
                        num_kv_heads=2, head_dim=4, prefix_share=True, **kw)


def _prompt(cache, sid, keys, n_tokens, seed):
    """Allocate + write a prompt, then commit it to the trie and release."""
    import jax.numpy as jnp
    t = cache.allocate(sid, n_tokens, keys=keys)
    hit = t.length
    if hit < n_tokens:
        rng = np.random.default_rng(seed)
        kv_shape = (2, n_tokens - hit, 2, 4)
        k = jnp.asarray(rng.normal(size=kv_shape), jnp.float32)
        v = jnp.asarray(rng.normal(size=kv_shape), jnp.float32)
        cache.write_prompt(sid, k, v, start=hit)
    cache.insert(sid, keys)
    cache.free(sid)
    return hit


def test_promoted_kv_bitmatches_demoted():
    """Round trip HBM -> host store -> (disk .npz) -> HBM: the promoted
    block's K/V must equal the original bit for bit (property 3)."""
    # host tier holds 8 of the 12 demoted blocks: the probe chain's 4 (the
    # oldest) overflow on into the disk spill, so the round trip crosses
    # BOTH cold tiers
    cache = _tiered_cache(host_cache_blocks=8, disk_cache_blocks=16)
    try:
        keys = _CHAINS[0][:4]
        _prompt(cache, 0, keys, 16, seed=1)
        want_k = np.asarray(cache.k_pool).copy()
        want_v = np.asarray(cache.v_pool).copy()
        blocks_of = {k: cache._mgr._trie[k] for k in keys}
        # flood: two filler prompts age all 4 blocks into the host tier,
        # and a third pushes the oldest on into the disk spill
        _prompt(cache, 1, _CHAINS[1][:4], 16, seed=2)
        _prompt(cache, 2, _CHAINS[2][:4], 16, seed=3)
        _prompt(cache, 3, _CHAINS[3][:4], 16, seed=4)
        assert cache._engine.drain(10.0)
        assert cache.probe(keys) == 0
        _, host_t, disk_t = cache.probe_tiers(keys)
        assert host_t + disk_t == 16 and disk_t > 0
        ticket = cache.promote_async(keys)
        assert ticket.wait(10.0)
        assert cache.promote_settle(ticket) == 4
        assert cache.probe(keys) == 16
        for k in keys:
            b_new = cache._mgr._trie[k]
            b_old = blocks_of[k]
            np.testing.assert_array_equal(
                np.asarray(cache.k_pool[:, b_new]), want_k[:, b_old])
            np.testing.assert_array_equal(
                np.asarray(cache.v_pool[:, b_new]), want_v[:, b_old])
        cache._mgr.check()
    finally:
        cache.close()


def test_corrupt_host_copy_falls_back_to_recompute():
    """A host copy whose bytes rotted must fail its checksum at promotion:
    the entry is DROPPED (never probed again, never scattered into the
    pool) and the prompt recomputes — stale KV is never served."""
    cache = _tiered_cache()
    try:
        keys = _CHAINS[0][:4]
        _prompt(cache, 0, keys, 16, seed=1)
        _prompt(cache, 1, _CHAINS[1][:4], 16, seed=2)
        _prompt(cache, 2, _CHAINS[2][:4], 16, seed=3)
        assert cache._engine.drain(10.0)
        pool_before = np.asarray(cache.k_pool).copy()
        # rot one stored block's bytes behind the checksum's back
        victim = keys[1]
        with cache._store_lock:
            k_np, v_np, crc = cache._host_store[victim]
            k_bad = k_np.copy()
            k_bad.ravel()[0] += 1.0
            cache._host_store[victim] = (k_bad, v_np, crc)
        ticket = cache.promote_async(keys)
        assert ticket.wait(10.0)
        # keys[0] lands; the corrupt block aborts-with-drop, and the walk
        # behind it (begun before the corruption was detectable) settles too
        committed = cache.promote_settle(ticket)
        assert committed < 4
        stats = cache.tier_stats()
        assert stats["copies_failed"] >= 1
        assert stats["in_flight"] == 0
        warm, host_t, _ = cache.probe_tiers(keys)
        assert warm == 4 and host_t == 0            # cold chain breaks at
                                                    # the dropped block
        assert victim not in cache._mgr._host       # dropped, not restored
        # the corrupt bytes never reached the device pool
        assert not np.isin(k_bad.ravel()[0],
                           np.asarray(cache.k_pool)).any() \
            or np.isin(k_bad.ravel()[0], pool_before).any()
        # recompute fallback: a new prompt with the same chain allocates
        # fresh blocks past the warm run and completes normally
        t = cache.allocate(9, 16, keys=keys)
        assert t.length < 16                        # suffix is recomputed
        cache.free(9)
        cache._mgr.check()
    finally:
        cache.close()


def test_lost_host_copy_aborts_promotion():
    """A host entry that vanished (store eviction race) is a lost copy:
    the promotion errors, the reserved block returns to the pool, and the
    key is dropped rather than re-probed forever."""
    cache = _tiered_cache()
    try:
        keys = _CHAINS[0][:4]
        _prompt(cache, 0, keys, 16, seed=1)
        _prompt(cache, 1, _CHAINS[1][:4], 16, seed=2)
        _prompt(cache, 2, _CHAINS[2][:4], 16, seed=3)
        assert cache._engine.drain(10.0)
        with cache._store_lock:
            del cache._host_store[keys[0]]          # lose the copy
        ticket = cache.promote_async(keys)
        assert ticket.wait(10.0)
        # promotion is per-block: the lost block aborts-with-drop, the
        # other three land — and the lost key is gone, not retried forever
        assert cache.promote_settle(ticket) == 3
        assert keys[0] not in cache._mgr._host
        assert cache.probe(keys) == 0               # chain broken at key 0
        free, live, cached, total = cache.accounting()
        assert free + live + cached == total        # reservation returned
    finally:
        cache.close()


def test_injected_copy_failure_returns_key_to_tier():
    """A transient copy failure (injected IOError, not a checksum mismatch)
    aborts WITHOUT dropping: the key returns to its tier for a later try."""
    cache = _tiered_cache()
    try:
        keys = _CHAINS[0][:4]
        _prompt(cache, 0, keys, 16, seed=1)
        _prompt(cache, 1, _CHAINS[1][:4], 16, seed=2)
        _prompt(cache, 2, _CHAINS[2][:4], 16, seed=3)
        assert cache._engine.drain(10.0)
        cache._engine.fail_keys = {keys[0]}
        ticket = cache.promote_async(keys)
        assert ticket.wait(10.0)
        assert cache.promote_settle(ticket) == 3    # per-block: rest land
        assert keys[0] in cache._mgr._host          # still retryable
        assert cache.probe(keys) == 0               # chain gated at key 0
        cache._engine.fail_keys = set()
        ticket = cache.promote_async(keys)          # only key 0 is cold now
        assert ticket.wait(10.0)
        assert cache.promote_settle(ticket) == 1    # retry succeeds
        assert cache.probe(keys) == 16
        cache._mgr.check()
    finally:
        cache.close()


def test_engine_shutdown_with_transfers_in_flight_drains_clean():
    """Shutdown while promotions are on the wire: queued jobs complete with
    a shutdown error, every waiter wakes, every reserved block aborts back
    to the pool — no leaked blocks, no hang (property 5)."""
    engine = BlockCopyEngine()
    engine.delay_s = 0.05                           # hold jobs on the wire
    cache = _tiered_cache(copy_engine=engine)
    try:
        keys = _CHAINS[0][:4]
        _prompt(cache, 0, keys, 16, seed=1)
        _prompt(cache, 1, _CHAINS[1][:4], 16, seed=2)
        _prompt(cache, 2, _CHAINS[2][:4], 16, seed=3)
        assert engine.drain(10.0)
        ticket = cache.promote_async(keys)
        assert cache._mgr.in_flight > 0
        engine.shutdown(wait=True)                  # transfers in flight
        assert ticket.wait(5.0), "shutdown left a waiter hanging"
        cache.promote_settle(ticket)
        assert cache._mgr.in_flight == 0
        free, live, cached, total = cache.accounting()
        assert free + live + cached == total, "shutdown leaked blocks"
        # post-shutdown submits complete immediately with the error
        job = engine.submit("promote", 123, lambda: 1)
        assert job.done.is_set() and job.error is not None
    finally:
        cache.close()


def test_reeviction_race_during_promotion():
    """Promotion in flight while fresh allocations keep the pool under
    pressure: the cascade may demote MORE blocks mid-promotion, but the
    in-flight reservation and conservation both hold throughout."""
    engine = BlockCopyEngine()
    engine.delay_s = 0.03
    cache = _tiered_cache(copy_engine=engine)
    try:
        keys = _CHAINS[0][:4]
        _prompt(cache, 0, keys, 16, seed=1)
        _prompt(cache, 1, _CHAINS[1][:4], 16, seed=2)
        _prompt(cache, 2, _CHAINS[2][:4], 16, seed=3)
        assert engine.drain(10.0)
        ticket = cache.promote_async(keys, max_blocks=2)
        # while the copies crawl, a new prompt churns the remaining blocks
        _prompt(cache, 3, _CHAINS[3][:2], 8, seed=4)
        assert ticket.wait(10.0)
        committed = cache.promote_settle(ticket)
        assert engine.drain(10.0)
        assert committed >= 0 and cache._mgr.in_flight == 0
        free, live, cached, total = cache.accounting()
        assert free + live + cached == total
        cache._mgr.check()
    finally:
        cache.close()
        engine.shutdown()
