"""Vectorized cost-model hot path: the batched `op_durations` must be
BIT-IDENTICAL to the scalar reference (same IEEE operation sequence), and the
Horner-loop `TTFTPredictor.predict` must match np.polyval exactly."""
import time

import numpy as np
import pytest

from repro.core.predictor import TTFTPredictor
from repro.sim.costmodel import (A100, A800, TPU_V5E, MODEL_SPECS,
                                 PrefillCostModel)

CASES = [
    (17, 0), (600, 0), (1000, 64), (4096, 512), (4097, 1000),
    (32768, 512), (32768, 2048), (2048, 2048),
]


@pytest.mark.parametrize("model", ["llama3-8b", "llama3-70b",
                                   "qwen3-30b-a3b"])
@pytest.mark.parametrize("hw", [A800, A100, TPU_V5E],
                         ids=lambda h: h.name)
def test_vectorized_op_durations_bit_identical(model, hw):
    cm = PrefillCostModel(MODEL_SPECS[model], hw)
    for tokens, chunk in CASES:
        vec = cm.op_durations(tokens, chunk)
        ref = cm.op_durations_scalar(tokens, chunk)
        assert vec.shape == ref.shape, (tokens, chunk)
        # bit-identical, not just close: the batched path replays the exact
        # scalar IEEE operation sequence (acceptance bound is 1e-9 relative;
        # equality is strictly stronger)
        np.testing.assert_array_equal(vec, ref, err_msg=f"{tokens}/{chunk}")


def test_vectorized_prefill_time_and_throughput_unchanged():
    cm = PrefillCostModel(MODEL_SPECS["llama3-8b"], A800)
    for tokens, chunk in CASES:
        ref = float(cm.op_durations_scalar(tokens, chunk).sum())
        assert cm.prefill_time(tokens, chunk) == ref


def test_vectorized_hot_path_speedup():
    """The chunked sweep hot path (fig18-style high-rate runs) must be
    substantially faster batched. Measured ~6-7x at 128 chunks; asserted at
    2x to stay robust on noisy CI runners."""
    cm = PrefillCostModel(MODEL_SPECS["llama3-8b"], A800)
    cm.op_durations(32768, 256), cm.op_durations_scalar(32768, 256)  # warmup

    def best_of(fn, repeats=3, loops=10):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            for _ in range(loops):
                fn(32768, 256)
            best = min(best, time.perf_counter() - t0)
        return best

    t_vec = best_of(cm.op_durations)
    t_ref = best_of(cm.op_durations_scalar)
    assert t_ref / t_vec >= 2.0, f"speedup only {t_ref / t_vec:.2f}x"


def test_predict_matches_polyval_bitwise():
    p = TTFTPredictor.fit(np.linspace(64, 32768, 64),
                          np.linspace(0.01, 3.0, 64) ** 1.3)
    for x in (0.0, 17, 500.5, 4096, 32768, 1e6, -5):
        ref = max(float(np.polyval(p.coeffs, max(float(x), 0.0))), p.floor)
        assert p.predict(x) == ref


def test_predict_many_matches_scalar_predict():
    p = TTFTPredictor.fit(np.linspace(64, 32768, 64),
                          np.linspace(0.01, 3.0, 64) ** 1.3)
    xs = np.array([0.0, 17.0, 500.5, 4096.0, 32768.0, 1e6, -5.0])
    np.testing.assert_array_equal(p.predict_many(xs),
                                  [p.predict(v) for v in xs])


def test_horner_cache_tracks_coeff_rebinding():
    """Online refit rebinds `coeffs`; predict must pick the new fit up."""
    p = TTFTPredictor(coeffs=np.array([1e-4, 0.0]))
    assert p.predict(100) == pytest.approx(1e-2)
    p.coeffs = np.array([2e-4, 0.0])
    assert p.predict(100) == pytest.approx(2e-2)
