"""Heterogeneous cluster simulation: per-instance hardware specs, capacity-
weighted and decode-aware dispatch, and online TTFT-predictor refit."""
import copy

import numpy as np

from repro.core.metrics import max_goodput
from repro.core.predictor import OnlineTTFTPredictor, TTFTPredictor
from repro.sim.cluster import ClusterSim, simulate_cluster
from repro.sim.costmodel import (A100, A800, TPU_V5E, MODEL_SPECS,
                                 PrefillCostModel, resolve_hardware)
from repro.sim.policies import preset
from repro.traces.qwentrace import TraceConfig, generate


def test_resolve_hardware_names_and_specs():
    assert resolve_hardware("a800") is A800
    assert resolve_hardware("A100-SXM4") is A100
    assert resolve_hardware(TPU_V5E) is TPU_V5E
    try:
        resolve_hardware("h9000")
        raise AssertionError("expected ValueError")
    except ValueError:
        pass


def test_hetero_pool_builds_per_instance_models():
    cost = PrefillCostModel(MODEL_SPECS["llama3-8b"], A800)
    sim = ClusterSim(cost, preset("flowprefill"),
                     hardware=[A800, A800, TPU_V5E])
    assert sim.num_instances == 3
    assert [c.hw.name for c in sim.instance_costs] == \
        [A800.name, A800.name, TPU_V5E.name]
    # faster hardware -> larger capacity (peak prefill throughput)
    assert sim.capacities[0] == sim.capacities[1] > sim.capacities[2]
    # per-hardware predictor cache: same-spec instances share one fit
    assert sim.instance_predictors[0] is sim.instance_predictors[1]
    assert sim.instance_predictors[2] is not sim.instance_predictors[0]


def test_capacity_weighted_routes_more_to_faster_instance():
    """On a mixed A800/TPU-v5e pool (~1.6x prefill capacity gap),
    capacity-weighted JSQ must route proportionally more work to the fast
    card than both blind cycling and hardware-blind least-loaded."""
    reqs = generate(TraceConfig(rate=10, duration=40, seed=1))
    share = {}
    att = {}
    for pol in ("round-robin", "least-loaded", "capacity-weighted"):
        res = simulate_cluster("flowprefill", reqs,
                               hardware=[A800, TPU_V5E], dispatch=pol)
        share[pol] = res.dispatched[0] / sum(res.dispatched)
        att[pol] = res.attainment
    assert share["capacity-weighted"] > 0.55            # skewed to A800
    assert share["capacity-weighted"] > share["least-loaded"] + 0.03
    assert abs(share["round-robin"] - 0.5) < 0.02       # blind cycling
    assert att["capacity-weighted"] > att["round-robin"]


def test_decode_aware_beats_load_blind_jsq_on_mixed_pool():
    """The fig19 acceptance claim: on a mixed A800/A100 pool with a paired
    decode stage and a tight TBT SLO, decode-aware dispatch achieves >= 1.15x
    the end-to-end goodput of hardware-blind least-loaded JSQ."""
    pool = [A800, A800, A100, A100]
    rates = [8, 12, 16, 20]
    goodput = {}
    for pol in ("least-loaded", "decode-aware"):
        atts = []
        for rate in rates:
            reqs = generate(TraceConfig(rate=rate, duration=40, seed=3,
                                        output_mean=256, tbt_slo=0.018))
            res = simulate_cluster("flowprefill", reqs, hardware=pool,
                                   decode_hardware=pool, decode_instances=4,
                                   dispatch=pol)
            atts.append(res.e2e_attainment)
        goodput[pol] = max_goodput(rates, atts)
    assert goodput["decode-aware"] >= 1.15 * goodput["least-loaded"], goodput


def test_decode_affinity_defaults():
    cost = PrefillCostModel(MODEL_SPECS["llama3-8b"], A800)
    sim = ClusterSim(cost, preset("flowprefill"), num_instances=2,
                     dispatch="decode-aware", decode_instances=2)
    assert sim.decode_affinity                     # paired handoff
    sim = ClusterSim(cost, preset("flowprefill"), num_instances=2,
                     dispatch="least-loaded", decode_instances=2)
    assert not sim.decode_affinity                 # least-batch join (PR 1)


def test_simulate_cluster_accepts_hardware_names():
    reqs = generate(TraceConfig(rate=4, duration=10, seed=0))
    res = simulate_cluster("flowprefill", reqs, hardware=["a800", "tpu-v5e"],
                           dispatch="capacity-weighted")
    assert sum(res.dispatched) == len(reqs)


# --- online predictor refit --------------------------------------------------


def test_online_predictor_unit_refit_converges():
    prior = TTFTPredictor(coeffs=np.array([5e-4, 0.1]))      # 2x-ish off
    true = TTFTPredictor(coeffs=np.array([2.5e-4, 0.05]))
    p = OnlineTTFTPredictor.from_predictor(prior)
    rng = np.random.default_rng(0)
    probe = [500.0, 2000.0, 8000.0, 20000.0]

    def err():
        return float(np.mean([abs(p.predict(n) - true.predict(n))
                              / true.predict(n) for n in probe]))

    before = err()
    for _ in range(64):
        n = float(rng.uniform(100, 30000))
        p.observe(n, true.predict(n))
    assert p.n_refits > 0
    assert err() < before * 0.05


def test_online_predictor_observe_is_thread_safe():
    """The real Proxy feeds observe() from every instance's scheduler thread;
    concurrent observes must neither mispair observations nor crash a refit
    with mismatched window arrays."""
    import threading

    p = OnlineTTFTPredictor(coeffs=np.array([1e-4, 0.0]), window=64,
                            min_points=4, refit_every=2)
    errors = []

    def feed(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(500):
                n = float(rng.uniform(100, 30000))
                p.observe(n, 1e-4 * n)
        except Exception as e:          # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=feed, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert p.n_observed == 2000
    assert p.n_refits > 0


def test_online_refit_shrinks_error_in_cluster_sim():
    """Predictor-feedback acceptance: an A800-fitted prior deployed on
    TPU-v5e instances converges to the instance's true cost curve after one
    online-refit run (error must shrink by well over 2x)."""
    spec = MODEL_SPECS["llama3-8b"]
    prior_cost = PrefillCostModel(spec, A800)
    true_cost = PrefillCostModel(spec, TPU_V5E)
    probe = np.linspace(256, 24576, 16)

    def err(predict):
        return float(np.mean(
            [abs(predict(n) - true_cost.prefill_time(int(n)))
             / true_cost.prefill_time(int(n)) for n in probe]))

    sim = ClusterSim(prior_cost, preset("flowprefill"), num_instances=2,
                     hardware=[TPU_V5E, TPU_V5E], dispatch="least-loaded",
                     online_refit=True)
    # deploy the WRONG-generation prior on both instances
    sim.instance_predictors = [sim.predictor] * 2
    before = err(sim.predictor.predict)
    reqs = generate(TraceConfig(rate=8, duration=20, seed=3))
    sim.run(copy.deepcopy(reqs))
    after = float(np.mean([err(p.predict) for p in sim.run_predictors]))
    assert before > 0.2                      # the prior really is off
    assert after < before * 0.5, (before, after)
    # engine predictors were refit; the seed prior object is untouched
    assert all(p.n_refits > 0 for p in sim.run_predictors)
    assert err(sim.predictor.predict) == before


def test_online_refit_keeps_observing_across_hardware():
    """Two different-speed instances each converge to their OWN curve."""
    spec = MODEL_SPECS["llama3-8b"]
    cost = PrefillCostModel(spec, A800)
    sim = ClusterSim(cost, preset("flowprefill"),
                     hardware=[A800, TPU_V5E], dispatch="capacity-weighted",
                     online_refit=True)
    reqs = generate(TraceConfig(rate=8, duration=20, seed=5))
    sim.run(copy.deepcopy(reqs))
    p_fast, p_slow = sim.run_predictors
    assert p_fast.n_observed > 0 and p_slow.n_observed > 0
    # the slow instance's learned curve predicts higher latency at scale
    assert p_slow.predict(16384) > p_fast.predict(16384)
